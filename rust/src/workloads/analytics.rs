//! Filter-then-sum analytics over a vertical column table — the
//! vertical-arithmetic flagship workload (`SELECT SUM(v) WHERE v < T`
//! over a W-bit column).
//!
//! The column transposes into W bit-plane rows ([`VerticalLayout`]),
//! the predicate compiles as a constant-threshold compare
//! (`arith::kernel_const`, whose borrow chain mostly folds), and the
//! masked sum runs the plane-AND batch in-DRAM before the host
//! tree-reduces W popcounts. Under PUMA every plane co-locates via
//! `pim_alloc_align` hints and the whole pipeline stays in-DRAM; under
//! the baseline allocators the same compiled batches fall back row by
//! row to the CPU path — that is the compiled-vs-CPU-fallback
//! comparison the sweep quantifies, across bit-widths and all four
//! allocators.
//!
//! Every cell is verified twice: the predicate mask bit-for-bit and
//! the masked sum value against host-side scalar arithmetic.
//!
//! Host-boundary accounting (DESIGN.md §12): columns are fetched
//! through the system's resident-column cache (`System::column`
//! — transpose once, query many; each kernel of a cell re-fetches by
//! id, so the second kernel and every warm repeat is a cache hit), the
//! scratch pool persists across cells (its size-classed free lists
//! absorb width changes with zero net allocator traffic), and every
//! cell reports `host_ns_per_elem` — the measured wall-clock cost of
//! column fetch plus mask readback, per element.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::alloc::traits::Allocator;
use crate::coordinator::system::{System, SystemConfig};
use crate::dram::address::InterleaveScheme;
use crate::dram::energy::EnergyParams;
use crate::dram::timing::TimingParams;
use crate::os::process::Pid;
use crate::pud::arith::{
    self, ArithOp, Column, LayoutSpec, ShardedLayout, ShardedScratch,
    VerticalLayout,
};
use crate::pud::compiler::CompileStats;
use crate::pud::legality::CauseCounts;
use crate::util::rng::Pcg64;
use crate::workloads::microbench::AllocatorKind;

/// Analytics workload parameters.
#[derive(Debug, Clone)]
pub struct AnalyticsConfig {
    /// Column elements. The default gives one full DRAM row per
    /// bit-plane (8 KiB rows → 64 Ki elements).
    pub elems: usize,
    /// Bit-widths to sweep.
    pub widths: Vec<u32>,
    /// Threshold as a fraction of the value range: `T = frac · 2^W`.
    pub threshold_frac: f64,
    pub huge_pages: usize,
    pub puma_pages: usize,
    pub churn_rounds: usize,
    pub seed: u64,
}

impl Default for AnalyticsConfig {
    fn default() -> Self {
        Self {
            elems: 64 * 1024,
            widths: vec![4, 8, 16],
            threshold_frac: 0.5,
            huge_pages: 16,
            puma_pages: 8,
            churn_rounds: 2_000,
            seed: 0xA11A,
        }
    }
}

/// One analytics cell: a W-bit column on one allocator, compiled
/// predicate + masked sum, verified against host scalar arithmetic.
#[derive(Debug, Clone)]
pub struct AnalyticsResult {
    pub allocator: &'static str,
    pub width: u32,
    pub elems: usize,
    pub threshold: u64,
    /// Rows passing the predicate.
    pub matches: u64,
    /// The verified aggregate.
    pub sum: u128,
    /// Compile stats of the threshold-compare kernel (constant bits
    /// folded).
    pub compile: CompileStats,
    /// Hazard waves of the compare batch.
    pub waves: usize,
    /// Serial-equivalent simulated ns (compare + mask batches).
    pub sim_ns: f64,
    /// Bank-parallel completion ns (compare + mask batches).
    pub elapsed_ns: f64,
    pub pud_rows: u64,
    pub fallback_rows: u64,
    /// Per-cause attribution of `fallback_rows` (which PUMA placement
    /// requirement each fallback row violated).
    pub fallback_causes: CauseCounts,
    /// Analytic in-DRAM AAPs per element of the compare kernel — the
    /// W-bit op-cost accounting (`pud::isa::batch_cost`).
    pub aaps_per_elem: f64,
    /// Scratch-pool resident high water (the pool persists across
    /// cells; its size classes absorb width changes).
    pub pool_high_water: usize,
    /// Fresh allocator leases the scratch pool took during this cell —
    /// zero once the pool is warm for the cell's size classes.
    pub pool_leases: u64,
    /// Column-cache hits (resident + host image) accrued by this cell;
    /// the sum kernel's re-fetch makes every cell score at least one.
    pub col_hits: u64,
    /// Column-cache misses accrued by this cell — the first touch of a
    /// width transposes and stores, warm repeats score zero.
    pub col_misses: u64,
    /// Measured wall-clock host-boundary cost per element: column
    /// fetch (blocked transpose + store on a miss) plus mask readback.
    pub host_ns_per_elem: f64,
}

impl AnalyticsResult {
    /// In-DRAM fraction of the cell's batched rows.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }
}

/// The swept threshold for a width: `frac · 2^W`, clamped into
/// `[1, 2^W - 1]` so the predicate never degenerates.
pub fn threshold(width: u32, frac: f64) -> u64 {
    let span = (1u64 << width.min(63)) as f64;
    ((span * frac) as u64).clamp(1, arith::width_mask(width))
}

/// Run one cell on an already-booted system. The caller owns system,
/// allocator, and scratch pool so a sweep reuses them across widths:
/// the column comes from the resident-column cache (transpose once,
/// query many — both kernels fetch it by id, so the sum fetch and
/// every warm repeat is a hit) and scratch stays parked in the pool's
/// size classes between cells instead of round-tripping the allocator.
pub fn run_cell(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &AnalyticsConfig,
    width: u32,
    pools: &mut ShardedScratch,
) -> Result<AnalyticsResult> {
    ensure!(
        (1..=arith::MAX_WIDTH).contains(&width),
        "width {width} out of kernel range"
    );
    let thr = threshold(width, cfg.threshold_frac);
    let mask_bits = arith::width_mask(width);
    let mut rng = Pcg64::new(cfg.seed ^ (width as u64) << 8);
    let values: Vec<u64> =
        (0..cfg.elems).map(|_| rng.next_u64() & mask_bits).collect();

    let stats0 = sys.column_cache_stats();
    let leases0 = pools.leases();

    // the column is keyed by width and versioned by the seed that
    // generated it; a miss transposes (blocked) and stores, a hit
    // returns the resident planes untouched
    let t = Instant::now();
    let col = sys.column(
        alloc,
        pid,
        width as u64,
        cfg.seed,
        width,
        &values,
        LayoutSpec::Flat,
    )?;
    let mut host_ns = t.elapsed().as_nanos() as f64;
    let mask = VerticalLayout::alloc_with_hint(
        sys, alloc, pid, 1, cfg.elems, col.hint(),
    )?;
    let mask_col = Column::Flat(mask.clone());

    // compiled predicate: v < T with T's bits folded at compile time,
    // served from the system's (op, width, T) program cache
    let rep = sys.arith_const(
        alloc,
        pid,
        ArithOp::CmpLt,
        thr,
        &col,
        &mask_col,
        pools,
    )?;

    // verify the mask bit-for-bit against scalar compares
    let t = Instant::now();
    let mask_row = sys.read_virt(pid, mask.planes()[0], mask.plane_len())?;
    host_ns += t.elapsed().as_nanos() as f64;
    for (i, &v) in values.iter().enumerate() {
        let got = (mask_row[i / 8] >> (i % 8)) & 1 == 1;
        ensure!(
            got == (v < thr),
            "{name}: mask bit {i} diverged ({v} vs threshold {thr})"
        );
    }
    let matches = arith::popcount_live(&mask_row, cfg.elems);

    // filter-then-sum: in-DRAM masking, host tree reduction; the
    // column re-fetch is a resident-cache hit (no transpose, no store)
    let t = Instant::now();
    let col = sys.column(
        alloc,
        pid,
        width as u64,
        cfg.seed,
        width,
        &values,
        LayoutSpec::Flat,
    )?;
    host_ns += t.elapsed().as_nanos() as f64;
    let (sum, sum_rep) =
        sys.column_sum(alloc, pid, &col, Some(&mask_col), pools)?;
    let want: u128 = values
        .iter()
        .filter(|v| **v < thr)
        .map(|v| *v as u128)
        .sum();
    ensure!(
        sum == want,
        "{name}: masked sum diverged ({sum} vs {want})"
    );
    let sum_rep = sum_rep.expect("masked sum submits a batch");

    let cost = arith::kernel_cost(
        ArithOp::CmpLt,
        width,
        col.as_flat().expect("flat spec").plane_len(),
        sys.os.scheme.geometry.row_bytes as u64,
        &TimingParams::default(),
        &EnergyParams::default(),
    );
    // only the mask is per-cell transient; the column stays resident
    // in the cache and the scratch stays parked in the pool
    mask.free(sys, alloc, pid)?;
    let stats1 = sys.column_cache_stats();

    Ok(AnalyticsResult {
        allocator: name,
        width,
        elems: cfg.elems,
        threshold: thr,
        matches,
        sum,
        compile: rep.stats.clone(),
        waves: rep.batch.waves,
        sim_ns: rep.batch.total_ns + sum_rep.batch.total_ns,
        elapsed_ns: rep.batch.elapsed_ns + sum_rep.batch.elapsed_ns,
        pud_rows: rep.pud_rows + sum_rep.pud_rows,
        fallback_rows: rep.fallback_rows + sum_rep.fallback_rows,
        fallback_causes: {
            let mut c = rep.fallback_causes;
            c.merge(&sum_rep.fallback_causes);
            c
        },
        aaps_per_elem: cost.aaps as f64 / cfg.elems as f64,
        pool_high_water: pools.high_water(),
        pool_leases: pools.leases() - leases0,
        col_hits: (stats1.resident_hits + stats1.host_hits)
            - (stats0.resident_hits + stats0.host_hits),
        col_misses: (stats1.resident_misses + stats1.host_misses)
            - (stats0.resident_misses + stats0.host_misses),
        host_ns_per_elem: host_ns / cfg.elems.max(1) as f64,
    })
}

/// Run the width sweep on one allocator: one system, process, and
/// scratch pool reused across widths. Columns stay resident in the
/// cache and scratch parked in the pool's size classes for the whole
/// sweep; both retire in one shot at the end.
pub fn run(
    scheme: InterleaveScheme,
    cfg: &AnalyticsConfig,
    kind: AllocatorKind,
) -> Result<Vec<AnalyticsResult>> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: None,
        ..Default::default()
    })?;
    let pid = sys.spawn();
    let mut alloc = kind.build(&mut sys, cfg.puma_pages)?;
    let mut pools = ShardedScratch::new();
    let mut out = Vec::with_capacity(cfg.widths.len());
    for &w in &cfg.widths {
        out.push(run_cell(
            &mut sys,
            alloc.as_mut(),
            pid,
            kind.name(),
            cfg,
            w,
            &mut pools,
        )?);
    }
    sys.trim_pools(alloc.as_mut(), pid, &mut pools, 0)?;
    sys.flush_columns(alloc.as_mut(), pid)?;
    Ok(out)
}

/// Sweep allocators x widths, one fresh system per allocator.
pub fn sweep(
    scheme: &InterleaveScheme,
    cfg: &AnalyticsConfig,
    kinds: &[AllocatorKind],
) -> Result<Vec<AnalyticsResult>> {
    let mut out = Vec::with_capacity(kinds.len() * cfg.widths.len());
    for kind in kinds {
        out.extend(run(scheme.clone(), cfg, *kind)?);
    }
    Ok(out)
}

/// Sharded-analytics scale sweep parameters (DESIGN.md §11): the same
/// filter-then-sum aggregate, with the column partitioned into S
/// bank-disjoint shards executed MIMDRAM-style in one batch.
#[derive(Debug, Clone)]
pub struct ShardedConfig {
    /// Column elements; the default (1 Mi) gives 16 DRAM rows per
    /// unsharded bit-plane, so sharding has rows to split.
    pub elems: usize,
    /// Bit-widths to sweep.
    pub widths: Vec<u32>,
    /// Shard counts to sweep (S = 1 is the fully co-located
    /// single-subarray layout the unsharded paper placement produces).
    pub shards: Vec<usize>,
    /// Threshold as a fraction of the value range.
    pub threshold_frac: f64,
    pub huge_pages: usize,
    pub puma_pages: usize,
    pub churn_rounds: usize,
    pub seed: u64,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        Self {
            elems: 1 << 20,
            widths: vec![8, 16],
            shards: vec![1, 2, 4, 8, 16],
            threshold_frac: 0.5,
            huge_pages: 64,
            puma_pages: 48,
            churn_rounds: 2_000,
            seed: 0xA11A,
        }
    }
}

impl ShardedConfig {
    /// The unsharded-cell view of this configuration (the reference
    /// every sharded cell is verified bit-identical against).
    fn as_analytics(&self) -> AnalyticsConfig {
        AnalyticsConfig {
            elems: self.elems,
            widths: self.widths.clone(),
            threshold_frac: self.threshold_frac,
            huge_pages: self.huge_pages,
            puma_pages: self.puma_pages,
            churn_rounds: self.churn_rounds,
            seed: self.seed,
        }
    }
}

/// One sharded-analytics cell: a W-bit column split into S shards on
/// one allocator, verified bit-identical against the unsharded path
/// and host scalar arithmetic.
#[derive(Debug, Clone)]
pub struct ShardedResult {
    pub allocator: &'static str,
    pub width: u32,
    /// Shard count requested by the sweep.
    pub shards: usize,
    /// Shards actually materialized (lower for tiny columns).
    pub shard_count: usize,
    pub elems: usize,
    pub threshold: u64,
    pub matches: u64,
    pub sum: u128,
    /// Compare-kernel compile stats; `compiles == 0` once the program
    /// cache is warm.
    pub compile: CompileStats,
    /// Hazard waves across the compare + mask batches.
    pub waves: usize,
    /// Serial-equivalent simulated ns (compare + mask batches).
    pub sim_ns: f64,
    /// Bank-parallel completion ns (compare + mask batches) — THE
    /// sharding metric: near-linear drop in min(S, banks).
    pub elapsed_ns: f64,
    pub pud_rows: u64,
    pub fallback_rows: u64,
    /// Per-cause attribution of `fallback_rows` (which PUMA placement
    /// requirement each fallback row violated).
    pub fallback_causes: CauseCounts,
    /// Total resident high water across the per-shard scratch pools.
    pub pool_high_water: usize,
    /// Fresh allocator leases the per-shard pools took during this
    /// cell — zero once the pools are warm for the shard's classes.
    pub pool_leases: u64,
    /// Column-cache hits (resident + host image) accrued by this cell;
    /// sharded builds slice the flat cell's host image, so even the
    /// first S of a width scores host-image hits.
    pub col_hits: u64,
    /// Column-cache misses accrued by this cell.
    pub col_misses: u64,
    /// Measured wall-clock host-boundary cost per element: sharded
    /// column fetch plus the per-shard mask readback.
    pub host_ns_per_elem: f64,
}

impl ShardedResult {
    /// In-DRAM fraction of the cell's batched rows.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }
}

/// Run one sharded cell on an already-booted system: allocate the
/// column as S bank-spread shards, run the cached constant-threshold
/// compare and the masked sum as one batch each, and verify the mask
/// bit-for-bit plus the sum against host scalar arithmetic (the caller
/// additionally checks the sum against the unsharded path).
pub fn run_cell_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &ShardedConfig,
    width: u32,
    shards: usize,
    pools: &mut ShardedScratch,
) -> Result<ShardedResult> {
    ensure!(
        (1..=arith::MAX_WIDTH).contains(&width),
        "width {width} out of kernel range"
    );
    let thr = threshold(width, cfg.threshold_frac);
    let mask_bits = arith::width_mask(width);
    // same generator as the unsharded cell, so results are comparable
    let mut rng = Pcg64::new(cfg.seed ^ (width as u64) << 8);
    let values: Vec<u64> =
        (0..cfg.elems).map(|_| rng.next_u64() & mask_bits).collect();

    let stats0 = sys.column_cache_stats();
    let leases0 = pools.leases();

    // keyed like the flat cell (same id and version, shard-distinct
    // key): a miss slices the flat cell's once-transposed host image
    // into the shards instead of re-transposing the values
    let t = Instant::now();
    let col = sys.column(
        alloc,
        pid,
        width as u64,
        cfg.seed,
        width,
        &values,
        LayoutSpec::Sharded(shards),
    )?;
    let mut host_ns = t.elapsed().as_nanos() as f64;
    let mask = ShardedLayout::alloc_like(
        sys,
        alloc,
        pid,
        1,
        col.as_sharded().expect("sharded spec"),
    )?;
    let mask_col = Column::Sharded(mask.clone());

    let rep = sys.arith_const(
        alloc,
        pid,
        ArithOp::CmpLt,
        thr,
        &col,
        &mask_col,
        pools,
    )?;

    // verify the sharded mask bit-for-bit against scalar compares
    // (the sharded column_sum below re-reads the shards through the
    // padding-safe popcount path; no need to duplicate that here)
    let t = Instant::now();
    let got = mask.load(sys, pid)?;
    host_ns += t.elapsed().as_nanos() as f64;
    let matches = got.iter().filter(|&&g| g == 1).count() as u64;
    for (i, (&g, &v)) in got.iter().zip(&values).enumerate() {
        ensure!(
            (g == 1) == (v < thr),
            "{name}: S={shards} mask bit {i} diverged ({v} vs threshold {thr})"
        );
    }

    // filter-then-sum: every shard's in-DRAM masking in one batch; the
    // column re-fetch is a resident-cache hit
    let t = Instant::now();
    let col = sys.column(
        alloc,
        pid,
        width as u64,
        cfg.seed,
        width,
        &values,
        LayoutSpec::Sharded(shards),
    )?;
    host_ns += t.elapsed().as_nanos() as f64;
    let (sum, sum_rep) =
        sys.column_sum(alloc, pid, &col, Some(&mask_col), pools)?;
    let want: u128 = values
        .iter()
        .filter(|v| **v < thr)
        .map(|v| *v as u128)
        .sum();
    ensure!(
        sum == want,
        "{name}: S={shards} masked sum diverged ({sum} vs {want})"
    );
    let sum_rep = sum_rep.expect("masked sum submits a batch");

    let shard_count = col.spec().shards();
    // only the mask is per-cell transient; the sharded column stays
    // resident and scratch stays parked in the per-shard pools
    mask.free(sys, alloc, pid)?;
    let stats1 = sys.column_cache_stats();

    Ok(ShardedResult {
        allocator: name,
        width,
        shards,
        shard_count,
        elems: cfg.elems,
        threshold: thr,
        matches,
        sum,
        compile: rep.stats.clone(),
        waves: rep.batch.waves + sum_rep.batch.waves,
        sim_ns: rep.batch.total_ns + sum_rep.batch.total_ns,
        elapsed_ns: rep.batch.elapsed_ns + sum_rep.batch.elapsed_ns,
        pud_rows: rep.pud_rows + sum_rep.pud_rows,
        fallback_rows: rep.fallback_rows + sum_rep.fallback_rows,
        fallback_causes: {
            let mut c = rep.fallback_causes;
            c.merge(&sum_rep.fallback_causes);
            c
        },
        pool_high_water: pools.high_water(),
        pool_leases: pools.leases() - leases0,
        col_hits: (stats1.resident_hits + stats1.host_hits)
            - (stats0.resident_hits + stats0.host_hits),
        col_misses: (stats1.resident_misses + stats1.host_misses)
            - (stats0.resident_misses + stats0.host_misses),
        host_ns_per_elem: host_ns / cfg.elems.max(1) as f64,
    })
}

/// Run the shard sweep on one allocator: one system, scratch pools,
/// and column cache reused across widths and shard counts. Per width,
/// the *unsharded* cell runs first — its fetch also populates the host
/// image every sharded cell of the width slices — and every sharded
/// cell's aggregate is checked identical to it (bit-identity of the
/// mask and the scalar-reference sum are checked inside the cells).
pub fn run_sharded(
    scheme: InterleaveScheme,
    cfg: &ShardedConfig,
    kind: AllocatorKind,
) -> Result<Vec<ShardedResult>> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: None,
        ..Default::default()
    })?;
    let pid = sys.spawn();
    let mut alloc = kind.build(&mut sys, cfg.puma_pages)?;
    let acfg = cfg.as_analytics();
    // flat cells use pool 0, sharded cells pools 0..S; the size classes
    // keep the two shapes from evicting each other
    let mut flat_pools = ShardedScratch::new();
    let mut pools = ShardedScratch::new();
    let mut out = Vec::with_capacity(cfg.widths.len() * cfg.shards.len());
    for &w in &cfg.widths {
        let unsharded = run_cell(
            &mut sys,
            alloc.as_mut(),
            pid,
            kind.name(),
            &acfg,
            w,
            &mut flat_pools,
        )?;
        for &s in &cfg.shards {
            let cell = run_cell_sharded(
                &mut sys,
                alloc.as_mut(),
                pid,
                kind.name(),
                cfg,
                w,
                s,
                &mut pools,
            )?;
            ensure!(
                cell.sum == unsharded.sum && cell.matches == unsharded.matches,
                "{}: width {w} S={s} diverged from the unsharded path",
                kind.name()
            );
            out.push(cell);
        }
    }
    sys.trim_pools(alloc.as_mut(), pid, &mut flat_pools, 0)?;
    sys.trim_pools(alloc.as_mut(), pid, &mut pools, 0)?;
    sys.flush_columns(alloc.as_mut(), pid)?;
    Ok(out)
}

/// Sweep allocators x widths x shard counts, one fresh system per
/// allocator.
pub fn sweep_sharded(
    scheme: &InterleaveScheme,
    cfg: &ShardedConfig,
    kinds: &[AllocatorKind],
) -> Result<Vec<ShardedResult>> {
    let mut out =
        Vec::with_capacity(kinds.len() * cfg.widths.len() * cfg.shards.len());
    for kind in kinds {
        out.extend(run_sharded(scheme.clone(), cfg, *kind)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::FitPolicy;
    use crate::dram::geometry::DramGeometry;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
    }

    fn cfg() -> AnalyticsConfig {
        AnalyticsConfig {
            elems: 64 * 1024,
            widths: vec![4, 8],
            churn_rounds: 500,
            ..Default::default()
        }
    }

    #[test]
    fn threshold_stays_in_range() {
        assert_eq!(threshold(4, 0.5), 8);
        assert_eq!(threshold(8, 0.5), 128);
        assert_eq!(threshold(4, 0.0), 1);
        assert_eq!(threshold(4, 10.0), 15);
    }

    #[test]
    fn puma_cells_run_in_dram_and_verify() {
        let rs = run(scheme(), &cfg(), AllocatorKind::Puma(FitPolicy::WorstFit))
            .unwrap();
        assert_eq!(rs.len(), 2);
        for r in &rs {
            assert!(
                r.pud_row_fraction() > 0.95,
                "width {}: got {}",
                r.width,
                r.pud_row_fraction()
            );
            assert!(r.matches > 0 && r.sum > 0);
            assert!(r.aaps_per_elem > 0.0);
            // the wide cell leases at least W planes for masking
            assert!(r.pool_high_water >= r.width as usize);
            // the sum kernel re-fetches the resident column
            assert!(r.col_hits >= 1, "width {}: no column hit", r.width);
            assert!(r.host_ns_per_elem > 0.0);
        }
        // the first touch of each width transposes and stores
        assert!(rs.iter().all(|r| r.col_misses >= 1));
        // the compare kernel folds the constant threshold
        assert!(rs[0].compile.folds > 0);
    }

    #[test]
    fn warm_cells_hit_the_column_cache_and_lease_nothing() {
        let cfg = AnalyticsConfig {
            widths: vec![8],
            churn_rounds: 300,
            ..cfg()
        };
        let mut sys = System::boot(SystemConfig {
            scheme: scheme(),
            huge_pages: cfg.huge_pages,
            churn_rounds: cfg.churn_rounds,
            seed: cfg.seed,
            artifacts: None,
            ..Default::default()
        })
        .unwrap();
        let pid = sys.spawn();
        let kind = AllocatorKind::Puma(FitPolicy::WorstFit);
        let mut alloc = kind.build(&mut sys, cfg.puma_pages).unwrap();
        let mut pools = ShardedScratch::new();
        let cold = run_cell(
            &mut sys, alloc.as_mut(), pid, "puma", &cfg, 8, &mut pools,
        )
        .unwrap();
        assert!(cold.col_misses >= 1, "cold cell builds the column");
        assert!(cold.pool_leases > 0, "cold cell leases scratch");
        let warm = run_cell(
            &mut sys, alloc.as_mut(), pid, "puma", &cfg, 8, &mut pools,
        )
        .unwrap();
        assert_eq!(warm.col_misses, 0, "warm repeat rebuilds nothing");
        assert!(warm.col_hits >= 2, "both kernels hit the resident column");
        assert_eq!(
            warm.pool_leases, 0,
            "warm same-width repeat does zero allocator round-trips"
        );
        assert_eq!(warm.sum, cold.sum);
        assert_eq!(warm.matches, cold.matches);
        sys.trim_pools(alloc.as_mut(), pid, &mut pools, 0).unwrap();
        sys.flush_columns(alloc.as_mut(), pid).unwrap();
    }

    #[test]
    fn invalidated_columns_rebuild_instead_of_serving_stale_planes() {
        let mut sys = System::boot(SystemConfig {
            scheme: scheme(),
            huge_pages: 8,
            churn_rounds: 100,
            seed: 7,
            artifacts: None,
            ..Default::default()
        })
        .unwrap();
        let pid = sys.spawn();
        let kind = AllocatorKind::Puma(FitPolicy::WorstFit);
        let mut alloc = kind.build(&mut sys, 4).unwrap();
        let a: Vec<u64> = (0..1000).map(|i| i % 13).collect();
        let col = sys
            .column(alloc.as_mut(), pid, 1, 0, 4, &a, LayoutSpec::Flat)
            .unwrap();
        let flat = col.as_flat().unwrap();
        assert_eq!(flat.load(&mut sys, pid).unwrap(), a);
        // an in-place store mutates the planes behind the cache's
        // back; the invalidation hook forces the next fetch to rebuild
        let b: Vec<u64> = (0..1000).map(|i| (i + 5) % 13).collect();
        flat.store(&mut sys, pid, &b).unwrap();
        sys.invalidate_column(1);
        let col2 = sys
            .column(alloc.as_mut(), pid, 1, 0, 4, &b, LayoutSpec::Flat)
            .unwrap();
        assert_eq!(
            col2.as_flat().unwrap().load(&mut sys, pid).unwrap(),
            b,
            "stale plane served"
        );
        // a version bump rebuilds too, without an explicit invalidate
        let c: Vec<u64> = (0..1000).map(|i| (i + 9) % 13).collect();
        let col3 = sys
            .column(alloc.as_mut(), pid, 1, 1, 4, &c, LayoutSpec::Flat)
            .unwrap();
        assert_eq!(col3.as_flat().unwrap().load(&mut sys, pid).unwrap(), c);
        let stats = sys.column_cache_stats();
        assert!(stats.invalidations >= 1);
        sys.flush_columns(alloc.as_mut(), pid).unwrap();
        assert_eq!(sys.column_cache_stats().evictions, stats.evictions);
    }

    #[test]
    fn malloc_cells_fall_back_but_stay_correct() {
        let rs = run(scheme(), &cfg(), AllocatorKind::Malloc).unwrap();
        for r in &rs {
            // the batches are small (a handful of rows), so one
            // accidentally row-aligned frame pair moves the ratio a
            // lot; "mostly fallback" is the property, not exactly 0
            assert!(
                r.pud_row_fraction() < 0.2,
                "width {}: got {}",
                r.width,
                r.pud_row_fraction()
            );
            assert!(r.matches > 0);
        }
    }

    fn sharded_cfg() -> ShardedConfig {
        ShardedConfig {
            elems: 256 * 1024, // 4 rows per unsharded plane
            widths: vec![8],
            shards: vec![1, 4],
            huge_pages: 16,
            puma_pages: 8,
            churn_rounds: 500,
            ..Default::default()
        }
    }

    #[test]
    fn sharded_puma_cells_verify_and_speed_up() {
        let rs = run_sharded(
            scheme(),
            &sharded_cfg(),
            AllocatorKind::Puma(FitPolicy::WorstFit),
        )
        .unwrap();
        assert_eq!(rs.len(), 2);
        for r in &rs {
            assert!(
                r.pud_row_fraction() > 0.95,
                "S={}: got {}",
                r.shards,
                r.pud_row_fraction()
            );
            assert!(r.matches > 0 && r.sum > 0);
            assert_eq!(r.shard_count, r.shards);
            // every sharded build slices the flat cell's host image,
            // and the sum kernel re-fetches the resident shards
            assert!(r.col_hits >= 1, "S={}: no column hit", r.shards);
        }
        let s1 = rs.iter().find(|r| r.shards == 1).unwrap();
        let s4 = rs.iter().find(|r| r.shards == 4).unwrap();
        assert_eq!(s1.sum, s4.sum, "sharding is value-transparent");
        assert_eq!(s1.matches, s4.matches);
        assert!(
            s4.elapsed_ns < s1.elapsed_ns,
            "bank sharding must shrink the batch makespan: S=4 {} vs S=1 {}",
            s4.elapsed_ns,
            s1.elapsed_ns
        );
        // the warm program cache served the second shard count
        assert_eq!(s4.compile.compiles, 0, "repeat (op,width) compiles nothing");
    }

    #[test]
    fn sharded_malloc_cells_fall_back_but_stay_correct() {
        let cfg = ShardedConfig {
            shards: vec![4],
            ..sharded_cfg()
        };
        let rs = run_sharded(scheme(), &cfg, AllocatorKind::Malloc).unwrap();
        assert_eq!(rs.len(), 1);
        assert!(
            rs[0].pud_row_fraction() < 0.2,
            "got {}",
            rs[0].pud_row_fraction()
        );
        assert!(rs[0].matches > 0);
    }

    #[test]
    fn sharded_handles_ragged_and_degenerate_shards() {
        // elems not divisible by S (ragged tail shard) and S > elems
        // (degenerate one-element shards)
        let cfg = ShardedConfig {
            elems: 61,
            widths: vec![4],
            shards: vec![7, 100],
            huge_pages: 16,
            puma_pages: 8,
            churn_rounds: 300,
            ..Default::default()
        };
        let rs = run_sharded(
            scheme(),
            &cfg,
            AllocatorKind::Puma(FitPolicy::WorstFit),
        )
        .unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].shard_count, 7);
        assert_eq!(rs[1].shard_count, 61, "S > elems caps at one per elem");
        assert_eq!(rs[0].sum, rs[1].sum);
    }

    #[test]
    fn sweep_covers_allocators_by_width() {
        let rs = sweep(
            &scheme(),
            &AnalyticsConfig {
                widths: vec![4],
                churn_rounds: 300,
                ..cfg()
            },
            &[
                AllocatorKind::Malloc,
                AllocatorKind::Puma(FitPolicy::WorstFit),
            ],
        )
        .unwrap();
        assert_eq!(rs.len(), 2);
        let puma = rs.iter().find(|r| r.allocator == "puma").unwrap();
        let malloc = rs.iter().find(|r| r.allocator == "malloc").unwrap();
        assert!(puma.pud_row_fraction() > malloc.pud_row_fraction());
        assert_eq!(puma.sum, malloc.sum, "results are placement-independent");
    }
}
