//! Multi-clause predicate filter over bitmap columns — the compiler's
//! flagship workload.
//!
//! A table keeps one bitmap per predicate column; a filter like
//! `(c0 & c1 & !c2) | ((c3 ^ c4) & c5) | ...` selects the surviving
//! rows. Hand-lowering that onto the substrate is exactly what callers
//! had to do before `pud::compiler`: one temp buffer per intermediate,
//! allocated ad hoc (so placed wherever worst-fit lands, i.e. *not*
//! with the operands), one `submit` per op. The compiled path builds
//! the same predicate as one [`Expr`], lowers it through CSE + the
//! scratch register allocator, and executes it as ONE batch with
//! hint-co-located temporaries.
//!
//! [`run`] executes both paths on the same system and placements and
//! verifies each against the IR's scalar reference evaluator, so the
//! comparison isolates what the compiler buys: the PUD-row fraction
//! of the compiled path is strictly higher under PUMA, and the batch
//! overlaps independent clauses across banks.

use anyhow::{ensure, Result};
use rustc_hash::FxHashMap;

use crate::alloc::scratch::ScratchPool;
use crate::alloc::traits::Allocator;
use crate::coordinator::system::{System, SystemConfig};
use crate::dram::address::InterleaveScheme;
use crate::os::process::Pid;
use crate::pud::compiler::{CompileStats, Expr, ExprBuilder, ExprId, Node};
use crate::pud::isa::{BulkRequest, PudOp};
use crate::util::rng::Pcg64;
use crate::workloads::microbench::AllocatorKind;

/// Filter workload parameters.
#[derive(Debug, Clone)]
pub struct FilterConfig {
    /// Table rows (bits per bitmap column).
    pub rows: u64,
    /// Predicate clauses (each uses 2-3 bitmap columns).
    pub clauses: usize,
    /// Bit density of each column.
    pub density: f64,
    pub huge_pages: usize,
    pub puma_pages: usize,
    pub churn_rounds: usize,
    pub seed: u64,
}

impl Default for FilterConfig {
    fn default() -> Self {
        Self {
            rows: 256 * 1024, // 32 KiB per column
            clauses: 3,
            density: 0.3,
            huge_pages: 16,
            puma_pages: 8,
            churn_rounds: 2_000,
            seed: 0xF117E,
        }
    }
}

/// One filter cell: compiled vs hand-issued, same system, same
/// placements, both verified against the scalar reference.
#[derive(Debug, Clone)]
pub struct FilterResult {
    pub allocator: &'static str,
    pub clauses: usize,
    /// Distinct bitmap columns the predicate reads.
    pub columns: usize,
    pub rows: u64,
    /// Compiler-side stats (ops, scratch, CSE, NOT count, ...).
    pub compile: CompileStats,
    /// Hazard waves of the single compiled batch.
    pub waves: usize,
    /// Simulated ns, serial-equivalent, of the compiled batch.
    pub compiled_ns: f64,
    /// Bank-parallel completion time of the compiled batch.
    pub elapsed_ns: f64,
    pub compiled_pud_fraction: f64,
    /// Simulated ns of the hand-issued sequential lowering.
    pub hand_ns: f64,
    pub hand_pud_fraction: f64,
    /// Rows surviving the filter (equal on both paths, checked).
    pub matches: u64,
}

impl FilterResult {
    /// Simulated speedup of the compiled batch (bank-parallel) over
    /// the hand-issued serial lowering.
    pub fn speedup(&self) -> f64 {
        if self.elapsed_ns <= 0.0 {
            return 0.0;
        }
        self.hand_ns / self.elapsed_ns
    }
}

/// Build the standard `clauses`-clause predicate. Clause patterns
/// rotate (x, y, z fresh columns per clause):
///
/// * `x & y & !z`
/// * `(x ^ y) & z`
/// * `(x | y) & !z0` — reuses clause 0's negated column, so CSE has a
///   real duplicate to merge (2 fresh columns only)
///
/// Clauses are OR-ed together. Returns the expression and the number
/// of distinct columns it reads (8 for the canonical 3-clause form).
pub fn predicate(clauses: usize) -> (Expr, usize) {
    assert!(clauses >= 1, "need at least one clause");
    let mut b = ExprBuilder::new();
    let mut col = 0usize;
    let mut clause_ids: Vec<ExprId> = Vec::new();
    for i in 0..clauses {
        let id = match i % 3 {
            0 => {
                let x = b.leaf(col);
                let y = b.leaf(col + 1);
                let z = b.leaf(col + 2);
                col += 3;
                let nz = b.not(z);
                let xy = b.and(x, y);
                b.and(xy, nz)
            }
            1 => {
                let x = b.leaf(col);
                let y = b.leaf(col + 1);
                let z = b.leaf(col + 2);
                col += 3;
                let xy = b.xor(x, y);
                b.and(xy, z)
            }
            _ => {
                let x = b.leaf(col);
                let y = b.leaf(col + 1);
                col += 2;
                // column 2 is clause 0's negated column: a structural
                // duplicate of that NOT, merged by CSE
                let z = b.leaf(2);
                let nz = b.not(z);
                let xy = b.or(x, y);
                b.and(xy, nz)
            }
        };
        clause_ids.push(id);
    }
    let root = b.all_or(&clause_ids);
    (b.build(root), col)
}

/// The pre-compiler lowering: walk the DAG in topological order,
/// allocate a fresh, un-hinted temp buffer per intermediate, and
/// `submit` every op on its own. This is what every caller had to
/// hand-write — and what the compiler replaces. Temps are freed at
/// the end (the historical code usually didn't even do that; see
/// `workloads::setops`).
fn hand_lower(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    expr: &Expr,
    operands: &[u64],
    dst: u64,
    len: u64,
) -> Result<f64> {
    let mark = expr.reachable();
    let root = expr.root();
    let mut place: FxHashMap<ExprId, u64> = FxHashMap::default();
    let mut temps: Vec<u64> = Vec::new();
    let mut ns = 0.0;
    for (idx, node) in expr.nodes().iter().enumerate() {
        if !mark[idx] {
            continue;
        }
        let id = ExprId(idx as u32);
        if let Node::Leaf(i) = node {
            place.insert(id, operands[*i]);
            continue;
        }
        let p = if id == root {
            dst
        } else {
            let t = sys.alloc(alloc, pid, len)?;
            temps.push(t);
            t
        };
        match *node {
            Node::Leaf(_) => unreachable!("handled above"),
            Node::Const(v) => {
                ns += sys.submit(pid, &BulkRequest::new(PudOp::Zero, p, vec![], len))?;
                if v {
                    ns += sys
                        .submit(pid, &BulkRequest::new(PudOp::Not, p, vec![p], len))?;
                }
            }
            Node::Not(a) => {
                ns += sys.submit(
                    pid,
                    &BulkRequest::new(PudOp::Not, p, vec![place[&a]], len),
                )?;
            }
            Node::And(a, b) => {
                ns += sys.submit(
                    pid,
                    &BulkRequest::new(PudOp::And, p, vec![place[&a], place[&b]], len),
                )?;
            }
            Node::Or(a, b) => {
                ns += sys.submit(
                    pid,
                    &BulkRequest::new(PudOp::Or, p, vec![place[&a], place[&b]], len),
                )?;
            }
            Node::Xor(a, b) => {
                ns += sys.submit(
                    pid,
                    &BulkRequest::new(PudOp::Xor, p, vec![place[&a], place[&b]], len),
                )?;
            }
            Node::AndNot(a, b) => {
                ns += sys.submit(
                    pid,
                    &BulkRequest::new(PudOp::Not, p, vec![place[&b]], len),
                )?;
                ns += sys.submit(
                    pid,
                    &BulkRequest::new(PudOp::And, p, vec![place[&a], p], len),
                )?;
            }
        }
        place.insert(id, p);
    }
    if let Node::Leaf(i) = expr.node(root) {
        ns += sys.submit(
            pid,
            &BulkRequest::new(PudOp::Copy, dst, vec![operands[i]], len),
        )?;
    }
    for t in temps {
        sys.free(alloc, pid, t)?;
    }
    Ok(ns)
}

/// Run one filter cell: allocate + fill the columns with `kind`, run
/// the compiled batch and the hand-issued lowering on the same
/// placements, verify both against the scalar reference.
pub fn run(
    scheme: InterleaveScheme,
    cfg: &FilterConfig,
    kind: AllocatorKind,
) -> Result<FilterResult> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: None,
        ..Default::default()
    })?;
    let pid = sys.spawn();
    let mut alloc = kind.build(&mut sys, cfg.puma_pages)?;
    let (expr, columns) = predicate(cfg.clauses);
    let len = crate::pud::arith::plane_bytes(cfg.rows as usize);

    // columns: first via alloc, the rest hint-aligned (paper protocol)
    let first = sys.alloc(alloc.as_mut(), pid, len)?;
    let mut cols = vec![first];
    for _ in 1..columns {
        cols.push(sys.alloc_align(alloc.as_mut(), pid, len, first)?);
    }
    let dst = sys.alloc_align(alloc.as_mut(), pid, len, first)?;
    let mut rng = Pcg64::new(cfg.seed);
    let mut truth: Vec<Vec<u8>> = Vec::with_capacity(columns);
    for &va in &cols {
        let mut bits = vec![0u8; len as usize];
        for byte in bits.iter_mut() {
            for bit in 0..8 {
                if rng.chance(cfg.density) {
                    *byte |= 1 << bit;
                }
            }
        }
        sys.write_virt(pid, va, &bits)?;
        truth.push(bits);
    }
    let refs: Vec<&[u8]> = truth.iter().map(|v| v.as_slice()).collect();
    let want = expr.eval_bytes(&refs, len as usize)?;

    // --- compiled path: ONE submitted batch
    let mut pool = ScratchPool::new();
    let rep = sys.run_expr(alloc.as_mut(), pid, &expr, &cols, dst, len, &mut pool)?;
    let got = sys.read_virt(pid, dst, len)?;
    ensure!(
        got == want,
        "{}: compiled filter diverged from the scalar reference",
        kind.name()
    );

    // --- hand-issued path: same placements, ad-hoc temps, serial ops.
    // Scramble dst first: it currently holds the compiled result, and
    // the hand path must be verified on its own output.
    sys.write_virt(pid, dst, &vec![0xEEu8; len as usize])?;
    let (pud0, fb0) = (sys.coord.stats.pud_rows, sys.coord.stats.fallback_rows);
    let hand_ns = hand_lower(&mut sys, alloc.as_mut(), pid, &expr, &cols, dst, len)?;
    let hand_pud = sys.coord.stats.pud_rows - pud0;
    let hand_fb = sys.coord.stats.fallback_rows - fb0;
    let got = sys.read_virt(pid, dst, len)?;
    ensure!(
        got == want,
        "{}: hand-lowered filter diverged from the scalar reference",
        kind.name()
    );

    let hand_total = hand_pud + hand_fb;
    let matches = live_bit_count(&want, cfg.rows);
    Ok(FilterResult {
        allocator: kind.name(),
        clauses: cfg.clauses,
        columns,
        rows: cfg.rows,
        compile: rep.stats.clone(),
        waves: rep.batch.waves,
        compiled_ns: rep.batch.total_ns,
        elapsed_ns: rep.batch.elapsed_ns,
        compiled_pud_fraction: rep.pud_row_fraction(),
        hand_ns,
        hand_pud_fraction: if hand_total == 0 {
            0.0
        } else {
            hand_pud as f64 / hand_total as f64
        },
        matches,
    })
}

/// Set bits among the first `rows` bit positions of `bits` (LSB-first
/// within each byte, as `fill` writes them). The final byte's padding
/// bits — which the random column fill and NOT results can set — are
/// excluded, so the count never reports rows that do not exist.
fn live_bit_count(bits: &[u8], rows: u64) -> u64 {
    let mut total: u64 = bits.iter().map(|b| b.count_ones() as u64).sum();
    let pad = bits.len() as u64 * 8 - rows;
    if pad > 0 {
        let last = *bits.last().expect("pad > 0 implies a final byte");
        let pad_mask = 0xFFu8 << (8 - pad as u32);
        total -= (last & pad_mask).count_ones() as u64;
    }
    total
}

/// Sweep clause counts x allocators, one fresh system per cell.
pub fn sweep(
    scheme: &InterleaveScheme,
    cfg: &FilterConfig,
    clause_counts: &[usize],
    kinds: &[AllocatorKind],
) -> Result<Vec<FilterResult>> {
    let mut out = Vec::with_capacity(clause_counts.len() * kinds.len());
    for &clauses in clause_counts {
        for kind in kinds {
            let cell_cfg = FilterConfig {
                clauses,
                ..cfg.clone()
            };
            out.push(run(scheme.clone(), &cell_cfg, *kind)?);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::FitPolicy;
    use crate::dram::geometry::DramGeometry;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
    }

    fn cfg() -> FilterConfig {
        FilterConfig {
            rows: 128 * 1024, // 16 KiB columns
            churn_rounds: 500,
            ..Default::default()
        }
    }

    #[test]
    fn live_bit_count_excludes_padding() {
        assert_eq!(live_bit_count(&[0xFF, 0xFF], 16), 16);
        // 13 rows: the top 3 bits of the last byte are padding
        assert_eq!(live_bit_count(&[0xFF, 0xFF], 13), 13);
        assert_eq!(live_bit_count(&[0x00, 0xE0], 13), 0);
        assert_eq!(live_bit_count(&[0x00, 0x1F], 13), 5);
        assert_eq!(live_bit_count(&[], 0), 0);
    }

    #[test]
    fn canonical_predicate_reads_eight_columns() {
        let (e, columns) = predicate(3);
        assert_eq!(columns, 8);
        assert_eq!(e.n_leaves(), 8);
        let (_, c1) = predicate(1);
        assert_eq!(c1, 3);
    }

    #[test]
    fn puma_compiles_to_one_batch_and_beats_hand_lowering() {
        let r = run(scheme(), &cfg(), AllocatorKind::Puma(FitPolicy::WorstFit))
            .unwrap();
        assert_eq!(r.columns, 8);
        assert!(r.waves >= 1);
        assert!(
            r.compiled_pud_fraction > r.hand_pud_fraction,
            "compiled {} must beat hand-issued {}",
            r.compiled_pud_fraction,
            r.hand_pud_fraction
        );
        assert!(r.compiled_pud_fraction > 0.95, "got {}", r.compiled_pud_fraction);
        assert!(r.speedup() > 1.0, "speedup {}", r.speedup());
        assert!(r.compile.cse_hits >= 1, "shared !c2 must CSE");
        assert!(r.matches > 0);
    }

    #[test]
    fn malloc_filter_is_correct_but_all_fallback() {
        let r = run(scheme(), &cfg(), AllocatorKind::Malloc).unwrap();
        assert!(r.compiled_pud_fraction < 0.05);
        assert!(r.matches > 0);
    }

    #[test]
    fn sweep_covers_the_grid() {
        let rs = sweep(
            &scheme(),
            &cfg(),
            &[1, 2],
            &[
                AllocatorKind::Malloc,
                AllocatorKind::Puma(FitPolicy::WorstFit),
            ],
        )
        .unwrap();
        assert_eq!(rs.len(), 4);
        assert!(rs.iter().any(|r| r.allocator == "puma" && r.clauses == 2));
    }
}
