//! TPC-H-flavored analytics query workload over a multi-column
//! micro-table — the `pud::query` engine driven end-to-end across all
//! four allocators (DESIGN.md §13).
//!
//! The table has three `W`-bit columns with stable column-cache ids:
//! `custkey` (the semi-join key), `groupkey` (the grouping attribute,
//! TPC-H's `returnflag` stand-in), and `quantity` (the aggregated
//! measure). Three query shapes run per allocator:
//!
//! * **semi_join** — `lineitem ⋉ customer`-shaped: a residual
//!   predicate mask (`quantity < T`, cached `CmpLt`-const kernel) is
//!   ANDed into the key-presence semi-join mask built by
//!   [`query::semi_join_mask`], then `SUM(quantity)` over the
//!   survivors runs as a masked in-DRAM sum.
//! * **group_by** — `SELECT groupkey, COUNT(*), SUM(quantity) GROUP BY
//!   groupkey`: all per-group masks in ONE batch
//!   ([`query::group_by_sum`]), then a masked sum per group.
//! * **top_k** — the `ORDER BY quantity DESC LIMIT k` standin:
//!   threshold bisection ([`query::top_k`]), no sort, then
//!   `SUM(quantity)` over the selected rows.
//!
//! Every cell is verified inline against the scalar host oracles in
//! [`query::reference`] — mask bit-for-bit, aggregates exactly — and
//! the sharded twins are additionally cross-checked against the flat
//! cells. Columns are fetched through the resident-column cache
//! (transpose once, query many), kernels through the `(op, width,
//! const)` program cache, and each cell reports the measured
//! wall-clock host-boundary cost per row.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::alloc::traits::Allocator;
use crate::coordinator::system::{System, SystemConfig};
use crate::dram::address::InterleaveScheme;
use crate::os::process::Pid;
use crate::pud::arith::{
    self, ArithOp, Column, LayoutSpec, ShardedLayout, ShardedScratch,
    VerticalLayout,
};
use crate::pud::legality::CauseCounts;
use crate::pud::query::{self, QueryReport};
use crate::util::rng::Pcg64;
use crate::workloads::analytics::threshold;
use crate::workloads::microbench::AllocatorKind;

/// Column-cache ids of the micro-table (versioned by the config seed).
const CUSTKEY_ID: u64 = 101;
const GROUPKEY_ID: u64 = 102;
const QUANTITY_ID: u64 = 103;

/// Query-workload parameters.
#[derive(Debug, Clone)]
pub struct QueriesConfig {
    /// Table rows.
    pub rows: usize,
    /// Bit width of all three columns.
    pub width: u32,
    /// Distinct group keys (`groupkey = rng % groups`).
    pub groups: u64,
    /// Build-side key count for the semi-join (even keys of a key
    /// space twice that size, so ~half the probe rows match).
    pub build_keys: usize,
    /// Top-k selection size.
    pub k: u64,
    /// Residual-predicate threshold as a fraction of the value range.
    pub threshold_frac: f64,
    /// Shard count for the sharded twin cells (<= 1 skips them).
    pub shards: usize,
    pub huge_pages: usize,
    pub puma_pages: usize,
    pub churn_rounds: usize,
    pub seed: u64,
}

impl Default for QueriesConfig {
    fn default() -> Self {
        Self {
            rows: 64 * 1024,
            width: 8,
            groups: 8,
            build_keys: 16,
            k: 4096,
            threshold_frac: 0.5,
            shards: 4,
            huge_pages: 16,
            puma_pages: 8,
            churn_rounds: 2_000,
            seed: 0x7C_0F1E,
        }
    }
}

impl QueriesConfig {
    /// The deterministic micro-table this configuration describes:
    /// `(custkey, groupkey, quantity, build_keys)`.
    pub fn table(&self) -> (Vec<u64>, Vec<u64>, Vec<u64>, Vec<u64>) {
        let domain = 1u64 << self.width;
        let key_space = (2 * self.build_keys.max(1) as u64).min(domain);
        // even keys of the key space: present on the build side, so
        // ~half the probe rows find a partner (duplicates appear when
        // the domain clamps the key space — the engine dedups)
        let build: Vec<u64> = (0..self.build_keys)
            .map(|i| (2 * i as u64) % key_space)
            .collect();
        let mut rng = Pcg64::new(self.seed ^ 0xC057);
        let cust: Vec<u64> =
            (0..self.rows).map(|_| rng.below(key_space)).collect();
        let mut rng = Pcg64::new(self.seed ^ 0x6809);
        let grp: Vec<u64> =
            (0..self.rows).map(|_| rng.below(self.groups.max(1))).collect();
        let mut rng = Pcg64::new(self.seed ^ 0x5CA1);
        let mask = arith::width_mask(self.width);
        let qty: Vec<u64> =
            (0..self.rows).map(|_| rng.next_u64() & mask).collect();
        (cust, grp, qty, build)
    }
}

/// One query cell: one shape on one allocator (flat or sharded),
/// verified inline against the scalar host oracle.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub allocator: &'static str,
    /// `"semi_join"`, `"group_by"`, or `"top_k"`.
    pub shape: &'static str,
    pub width: u32,
    pub rows: usize,
    /// Shard count of the cell; 0 = flat (unsharded) path.
    pub shards: usize,
    /// Shape parameter: build-key count / group count / k.
    pub param: u64,
    /// Rows the shape's final mask selects (group_by: rows covered by
    /// the requested groups).
    pub matches: u64,
    /// The verified aggregate (`SUM(quantity)` over the selection).
    pub agg: u128,
    /// `submit_batch` round trips the shape issued.
    pub batches: usize,
    /// Hazard waves across those batches.
    pub waves: usize,
    /// Serial-equivalent simulated ns.
    pub sim_ns: f64,
    /// Bank-parallel simulated completion ns.
    pub elapsed_ns: f64,
    pub pud_rows: u64,
    pub fallback_rows: u64,
    /// Per-cause attribution of `fallback_rows` (which PUMA placement
    /// requirement each fallback row violated).
    pub fallback_causes: CauseCounts,
    /// Fresh kernel compiles (0 once the program cache is warm).
    pub compiles: usize,
    /// Top-k bisection rounds (0 for the other shapes).
    pub rounds: usize,
    /// Column-cache hits accrued by this cell.
    pub col_hits: u64,
    /// Column-cache misses accrued by this cell.
    pub col_misses: u64,
    /// Fresh scratch leases taken during this cell.
    pub pool_leases: u64,
    /// Scratch-pool resident high water after the cell.
    pub pool_high_water: usize,
    /// Measured wall-clock host-boundary cost per row: column fetch +
    /// mask/popcount readbacks.
    pub host_ns_per_elem: f64,
}

impl QueryResult {
    /// In-DRAM fraction of the cell's batched rows.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }
}

/// Column-cache + pool deltas shared by every cell.
struct CellMeter {
    hits0: u64,
    misses0: u64,
    leases0: u64,
}

impl CellMeter {
    fn start(sys: &System, leases0: u64) -> Self {
        let s = sys.column_cache_stats();
        Self {
            hits0: s.resident_hits + s.host_hits,
            misses0: s.resident_misses + s.host_misses,
            leases0,
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn finish(
        &self,
        sys: &System,
        name: &'static str,
        shape: &'static str,
        cfg: &QueriesConfig,
        shards: usize,
        param: u64,
        matches: u64,
        agg: u128,
        rep: &QueryReport,
        leases1: u64,
        high_water: usize,
        host_ns: f64,
    ) -> QueryResult {
        let s = sys.column_cache_stats();
        QueryResult {
            allocator: name,
            shape,
            width: cfg.width,
            rows: cfg.rows,
            shards,
            param,
            matches,
            agg,
            batches: rep.batches,
            waves: rep.waves,
            sim_ns: rep.total_ns,
            elapsed_ns: rep.elapsed_ns,
            pud_rows: rep.pud_rows,
            fallback_rows: rep.fallback_rows,
            fallback_causes: rep.fallback_causes,
            compiles: rep.compiles,
            rounds: rep.rounds,
            col_hits: (s.resident_hits + s.host_hits) - self.hits0,
            col_misses: (s.resident_misses + s.host_misses) - self.misses0,
            pool_leases: leases1 - self.leases0,
            pool_high_water: high_water,
            host_ns_per_elem: (host_ns + rep.host_ns as f64)
                / cfg.rows.max(1) as f64,
        }
    }
}

/// Bitmap semi-join with a residual predicate: mask = `custkey ∈
/// build` AND `quantity < T`, then `SUM(quantity)` over the mask.
pub fn run_cell_semi_join(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &QueriesConfig,
    pools: &mut ShardedScratch,
) -> Result<QueryResult> {
    ensure!(
        (1..=arith::MAX_WIDTH).contains(&cfg.width),
        "width {} out of kernel range",
        cfg.width
    );
    let (cust, _grp, qty, build) = cfg.table();
    let thr = threshold(cfg.width, cfg.threshold_frac);
    let meter = CellMeter::start(sys, pools.leases());

    // each column is used immediately after its own fetch (an evicted
    // column's planes are freed, so holding a layout across another
    // fetch would break under a tight column budget): quantity first
    // for the predicate, custkey next for the join
    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Flat,
    )?;
    let mut host_ns = t.elapsed().as_nanos() as f64;

    // residual predicate mask: quantity < T (cached const kernel)
    let pred = VerticalLayout::alloc_with_hint(
        sys,
        alloc,
        pid,
        1,
        cfg.rows,
        qty_col.hint(),
    )?;
    let pred_col = Column::Flat(pred.clone());
    let mut rep = QueryReport::default();
    let er = sys.arith_const(
        alloc,
        pid,
        ArithOp::CmpLt,
        thr,
        &qty_col,
        &pred_col,
        pools,
    )?;
    rep.absorb(&er);

    let t = Instant::now();
    let cust_col = sys.column(
        alloc,
        pid,
        CUSTKEY_ID,
        cfg.seed,
        cfg.width,
        &cust,
        LayoutSpec::Flat,
    )?;
    host_ns += t.elapsed().as_nanos() as f64;

    // key-presence semi-join AND the predicate, one batch
    let dst = VerticalLayout::alloc_with_hint(
        sys,
        alloc,
        pid,
        1,
        cfg.rows,
        cust_col.hint(),
    )?;
    rep.merge(&query::semi_join_mask(
        sys,
        alloc,
        pid,
        cust_col.as_flat().expect("flat spec"),
        &build,
        Some(pred.planes()[0]),
        &dst,
        pools.pool(0),
    )?);

    // verify the mask bit-for-bit against the scalar oracle
    let t = Instant::now();
    let mask_row = sys.read_virt(pid, dst.planes()[0], dst.plane_len())?;
    host_ns += t.elapsed().as_nanos() as f64;
    let pred_ref: Vec<bool> = qty.iter().map(|&v| v < thr).collect();
    let want = query::reference::semi_join(&cust, &build, Some(&pred_ref));
    for (i, &w) in want.iter().enumerate() {
        let got = (mask_row[i / 8] >> (i % 8)) & 1 == 1;
        ensure!(got == w, "{name}: semi-join mask bit {i} diverged");
    }
    let matches = arith::popcount_live(&mask_row, cfg.rows);

    // SUM(quantity) over the survivors, masked in-DRAM
    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Flat,
    )?;
    host_ns += t.elapsed().as_nanos() as f64;
    let dst_col = Column::Flat(dst.clone());
    let (agg, sum_rep) =
        sys.column_sum(alloc, pid, &qty_col, Some(&dst_col), pools)?;
    if let Some(er) = sum_rep {
        rep.absorb(&er);
    }
    let want_agg: u128 = qty
        .iter()
        .zip(&want)
        .filter(|(_, w)| **w)
        .map(|(v, _)| *v as u128)
        .sum();
    ensure!(agg == want_agg, "{name}: semi-join sum diverged ({agg} vs {want_agg})");

    pred.free(sys, alloc, pid)?;
    dst.free(sys, alloc, pid)?;
    Ok(meter.finish(
        sys,
        name,
        "semi_join",
        cfg,
        0,
        cfg.build_keys as u64,
        matches,
        agg,
        &rep,
        pools.leases(),
        pools.high_water(),
        host_ns,
    ))
}

/// Group-by aggregation: per-group `(COUNT, SUM(quantity))` with every
/// group mask in one batch.
pub fn run_cell_group_by(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &QueriesConfig,
    pools: &mut ShardedScratch,
) -> Result<QueryResult> {
    ensure!(
        (1..=arith::MAX_WIDTH).contains(&cfg.width),
        "width {} out of kernel range",
        cfg.width
    );
    ensure!(
        cfg.groups >= 1 && cfg.groups <= 1u64 << cfg.width,
        "{} group key(s) exceed the {}-bit domain",
        cfg.groups,
        cfg.width
    );
    let (_cust, grp, qty, _build) = cfg.table();
    let groups: Vec<u64> = (0..cfg.groups).collect();
    let meter = CellMeter::start(sys, pools.leases());

    let t = Instant::now();
    let grp_col = sys.column(
        alloc,
        pid,
        GROUPKEY_ID,
        cfg.seed,
        cfg.width,
        &grp,
        LayoutSpec::Flat,
    )?;
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Flat,
    )?;
    let host_ns = t.elapsed().as_nanos() as f64;

    let (aggs, rep) = query::group_by_sum(
        sys,
        alloc,
        pid,
        grp_col.as_flat().expect("flat spec"),
        qty_col.as_flat().expect("flat spec"),
        &groups,
        pools.pool(0),
    )?;

    let want = query::reference::group_by(&grp, &qty, &groups);
    ensure!(aggs.len() == want.len(), "{name}: group count diverged");
    for (a, (wc, ws)) in aggs.iter().zip(&want) {
        ensure!(
            a.count == *wc && a.sum == *ws,
            "{name}: group {} diverged (count {} vs {wc}, sum {} vs {ws})",
            a.group,
            a.count,
            a.sum
        );
    }
    let matches: u64 = aggs.iter().map(|a| a.count).sum();
    let agg: u128 = aggs.iter().map(|a| a.sum).sum();

    Ok(meter.finish(
        sys,
        name,
        "group_by",
        cfg,
        0,
        cfg.groups,
        matches,
        agg,
        &rep,
        pools.leases(),
        pools.high_water(),
        host_ns,
    ))
}

/// Top-k by threshold bisection, then `SUM(quantity)` over the
/// selected rows.
pub fn run_cell_top_k(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &QueriesConfig,
    pools: &mut ShardedScratch,
) -> Result<QueryResult> {
    ensure!(
        (1..=arith::MAX_WIDTH).contains(&cfg.width),
        "width {} out of kernel range",
        cfg.width
    );
    let (_cust, _grp, qty, _build) = cfg.table();
    let meter = CellMeter::start(sys, pools.leases());

    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Flat,
    )?;
    let mut host_ns = t.elapsed().as_nanos() as f64;

    let dst = VerticalLayout::alloc_with_hint(
        sys,
        alloc,
        pid,
        1,
        cfg.rows,
        qty_col.hint(),
    )?;
    let (tk, mut rep) = query::top_k(
        sys,
        alloc,
        pid,
        qty_col.as_flat().expect("flat spec"),
        cfg.k,
        &dst,
        pools.pool(0),
    )?;

    let (want_t, want_sel) = query::reference::top_k(&qty, cfg.k, cfg.width);
    ensure!(
        tk.threshold == want_t,
        "{name}: top-k threshold diverged ({} vs {want_t})",
        tk.threshold
    );
    let t = Instant::now();
    let mask_row = sys.read_virt(pid, dst.planes()[0], dst.plane_len())?;
    host_ns += t.elapsed().as_nanos() as f64;
    for (i, &w) in want_sel.iter().enumerate() {
        let got = (mask_row[i / 8] >> (i % 8)) & 1 == 1;
        ensure!(got == w, "{name}: top-k mask bit {i} diverged");
    }
    ensure!(
        tk.selected == want_sel.iter().filter(|&&s| s).count() as u64,
        "{name}: top-k selection count diverged"
    );

    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Flat,
    )?;
    host_ns += t.elapsed().as_nanos() as f64;
    let dst_col = Column::Flat(dst.clone());
    let (agg, sum_rep) =
        sys.column_sum(alloc, pid, &qty_col, Some(&dst_col), pools)?;
    if let Some(er) = sum_rep {
        rep.absorb(&er);
    }
    let want_agg: u128 = qty
        .iter()
        .zip(&want_sel)
        .filter(|(_, s)| **s)
        .map(|(v, _)| *v as u128)
        .sum();
    ensure!(agg == want_agg, "{name}: top-k sum diverged ({agg} vs {want_agg})");

    dst.free(sys, alloc, pid)?;
    Ok(meter.finish(
        sys,
        name,
        "top_k",
        cfg,
        0,
        cfg.k,
        tk.selected,
        agg,
        &rep,
        pools.leases(),
        pools.high_water(),
        host_ns,
    ))
}

/// Sharded twin of [`run_cell_semi_join`].
pub fn run_cell_semi_join_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &QueriesConfig,
    pools: &mut ShardedScratch,
) -> Result<QueryResult> {
    let (cust, _grp, qty, build) = cfg.table();
    let thr = threshold(cfg.width, cfg.threshold_frac);
    let meter = CellMeter::start(sys, pools.leases());

    // fetch order mirrors the flat cell: every column is used right
    // after its own fetch so tight column budgets stay legal
    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    let mut host_ns = t.elapsed().as_nanos() as f64;

    let pred = ShardedLayout::alloc_like(
        sys,
        alloc,
        pid,
        1,
        qty_col.as_sharded().expect("sharded spec"),
    )?;
    let pred_col = Column::Sharded(pred.clone());
    let mut rep = QueryReport::default();
    let er = sys.arith_const(
        alloc,
        pid,
        ArithOp::CmpLt,
        thr,
        &qty_col,
        &pred_col,
        pools,
    )?;
    rep.absorb(&er);

    let t = Instant::now();
    let cust_col = sys.column(
        alloc,
        pid,
        CUSTKEY_ID,
        cfg.seed,
        cfg.width,
        &cust,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    host_ns += t.elapsed().as_nanos() as f64;

    let dst = ShardedLayout::alloc_like(
        sys,
        alloc,
        pid,
        1,
        cust_col.as_sharded().expect("sharded spec"),
    )?;
    rep.merge(&query::semi_join_mask_sharded(
        sys,
        alloc,
        pid,
        cust_col.as_sharded().expect("sharded spec"),
        &build,
        Some(&pred),
        &dst,
        pools,
    )?);

    let t = Instant::now();
    let got = dst.load(sys, pid)?;
    host_ns += t.elapsed().as_nanos() as f64;
    let pred_ref: Vec<bool> = qty.iter().map(|&v| v < thr).collect();
    let want = query::reference::semi_join(&cust, &build, Some(&pred_ref));
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        ensure!(
            (g == 1) == w,
            "{name}: S={} semi-join mask bit {i} diverged",
            cfg.shards
        );
    }
    let matches = got.iter().filter(|&&g| g == 1).count() as u64;

    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    host_ns += t.elapsed().as_nanos() as f64;
    let dst_col = Column::Sharded(dst.clone());
    let (agg, sum_rep) =
        sys.column_sum(alloc, pid, &qty_col, Some(&dst_col), pools)?;
    if let Some(er) = sum_rep {
        rep.absorb(&er);
    }
    let want_agg: u128 = qty
        .iter()
        .zip(&want)
        .filter(|(_, w)| **w)
        .map(|(v, _)| *v as u128)
        .sum();
    ensure!(agg == want_agg, "{name}: S={} semi-join sum diverged", cfg.shards);

    pred.free(sys, alloc, pid)?;
    dst.free(sys, alloc, pid)?;
    Ok(meter.finish(
        sys,
        name,
        "semi_join",
        cfg,
        cfg.shards,
        cfg.build_keys as u64,
        matches,
        agg,
        &rep,
        pools.leases(),
        pools.high_water(),
        host_ns,
    ))
}

/// Sharded twin of [`run_cell_group_by`].
pub fn run_cell_group_by_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &QueriesConfig,
    pools: &mut ShardedScratch,
) -> Result<QueryResult> {
    let (_cust, grp, qty, _build) = cfg.table();
    let groups: Vec<u64> = (0..cfg.groups).collect();
    let meter = CellMeter::start(sys, pools.leases());

    let t = Instant::now();
    let grp_col = sys.column(
        alloc,
        pid,
        GROUPKEY_ID,
        cfg.seed,
        cfg.width,
        &grp,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    let host_ns = t.elapsed().as_nanos() as f64;

    let (aggs, rep) = query::group_by_sum_sharded(
        sys,
        alloc,
        pid,
        grp_col.as_sharded().expect("sharded spec"),
        qty_col.as_sharded().expect("sharded spec"),
        &groups,
        pools,
    )?;

    let want = query::reference::group_by(&grp, &qty, &groups);
    for (a, (wc, ws)) in aggs.iter().zip(&want) {
        ensure!(
            a.count == *wc && a.sum == *ws,
            "{name}: S={} group {} diverged",
            cfg.shards,
            a.group
        );
    }
    let matches: u64 = aggs.iter().map(|a| a.count).sum();
    let agg: u128 = aggs.iter().map(|a| a.sum).sum();

    Ok(meter.finish(
        sys,
        name,
        "group_by",
        cfg,
        cfg.shards,
        cfg.groups,
        matches,
        agg,
        &rep,
        pools.leases(),
        pools.high_water(),
        host_ns,
    ))
}

/// Sharded twin of [`run_cell_top_k`].
pub fn run_cell_top_k_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    name: &'static str,
    cfg: &QueriesConfig,
    pools: &mut ShardedScratch,
) -> Result<QueryResult> {
    let (_cust, _grp, qty, _build) = cfg.table();
    let meter = CellMeter::start(sys, pools.leases());

    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    let mut host_ns = t.elapsed().as_nanos() as f64;

    let dst = ShardedLayout::alloc_like(
        sys,
        alloc,
        pid,
        1,
        qty_col.as_sharded().expect("sharded spec"),
    )?;
    let (tk, mut rep) = query::top_k_sharded(
        sys,
        alloc,
        pid,
        qty_col.as_sharded().expect("sharded spec"),
        cfg.k,
        &dst,
        pools,
    )?;

    let (want_t, want_sel) = query::reference::top_k(&qty, cfg.k, cfg.width);
    ensure!(
        tk.threshold == want_t,
        "{name}: S={} top-k threshold diverged ({} vs {want_t})",
        cfg.shards,
        tk.threshold
    );
    let t = Instant::now();
    let got = dst.load(sys, pid)?;
    host_ns += t.elapsed().as_nanos() as f64;
    for (i, (&g, &w)) in got.iter().zip(&want_sel).enumerate() {
        ensure!(
            (g == 1) == w,
            "{name}: S={} top-k mask bit {i} diverged",
            cfg.shards
        );
    }

    let t = Instant::now();
    let qty_col = sys.column(
        alloc,
        pid,
        QUANTITY_ID,
        cfg.seed,
        cfg.width,
        &qty,
        LayoutSpec::Sharded(cfg.shards),
    )?;
    host_ns += t.elapsed().as_nanos() as f64;
    let dst_col = Column::Sharded(dst.clone());
    let (agg, sum_rep) =
        sys.column_sum(alloc, pid, &qty_col, Some(&dst_col), pools)?;
    if let Some(er) = sum_rep {
        rep.absorb(&er);
    }
    let want_agg: u128 = qty
        .iter()
        .zip(&want_sel)
        .filter(|(_, s)| **s)
        .map(|(v, _)| *v as u128)
        .sum();
    ensure!(agg == want_agg, "{name}: S={} top-k sum diverged", cfg.shards);

    dst.free(sys, alloc, pid)?;
    Ok(meter.finish(
        sys,
        name,
        "top_k",
        cfg,
        cfg.shards,
        cfg.k,
        tk.selected,
        agg,
        &rep,
        pools.leases(),
        pools.high_water(),
        host_ns,
    ))
}

/// Run all three shapes (flat, then sharded twins when `cfg.shards >
/// 1`) on one allocator: one system, process, scratch pools, and
/// column cache reused across shapes. Sharded cells are cross-checked
/// against their flat counterparts.
pub fn run(
    scheme: InterleaveScheme,
    cfg: &QueriesConfig,
    kind: AllocatorKind,
) -> Result<Vec<QueryResult>> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: None,
        ..Default::default()
    })?;
    let pid = sys.spawn();
    let mut alloc = kind.build(&mut sys, cfg.puma_pages)?;
    let mut flat_pools = ShardedScratch::new();
    let mut out = Vec::new();
    let flat = [
        run_cell_semi_join(
            &mut sys, alloc.as_mut(), pid, kind.name(), cfg, &mut flat_pools,
        )?,
        run_cell_group_by(
            &mut sys, alloc.as_mut(), pid, kind.name(), cfg, &mut flat_pools,
        )?,
        run_cell_top_k(
            &mut sys, alloc.as_mut(), pid, kind.name(), cfg, &mut flat_pools,
        )?,
    ];
    if cfg.shards > 1 {
        let mut pools = ShardedScratch::new();
        let sharded = [
            run_cell_semi_join_sharded(
                &mut sys, alloc.as_mut(), pid, kind.name(), cfg, &mut pools,
            )?,
            run_cell_group_by_sharded(
                &mut sys, alloc.as_mut(), pid, kind.name(), cfg, &mut pools,
            )?,
            run_cell_top_k_sharded(
                &mut sys, alloc.as_mut(), pid, kind.name(), cfg, &mut pools,
            )?,
        ];
        for (f, s) in flat.iter().zip(&sharded) {
            ensure!(
                f.matches == s.matches && f.agg == s.agg,
                "{}: sharded {} diverged from the flat path",
                kind.name(),
                s.shape
            );
        }
        sys.trim_pools(alloc.as_mut(), pid, &mut pools, 0)?;
        out.extend(flat);
        out.extend(sharded);
    } else {
        out.extend(flat);
    }
    sys.trim_pools(alloc.as_mut(), pid, &mut flat_pools, 0)?;
    sys.flush_columns(alloc.as_mut(), pid)?;
    Ok(out)
}

/// Sweep allocators, one fresh system per allocator.
pub fn sweep(
    scheme: &InterleaveScheme,
    cfg: &QueriesConfig,
    kinds: &[AllocatorKind],
) -> Result<Vec<QueryResult>> {
    let mut out = Vec::with_capacity(kinds.len() * 6);
    for kind in kinds {
        out.extend(run(scheme.clone(), cfg, *kind)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::FitPolicy;
    use crate::dram::geometry::DramGeometry;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
    }

    fn cfg() -> QueriesConfig {
        QueriesConfig {
            rows: 16 * 1024,
            k: 1024,
            churn_rounds: 500,
            ..Default::default()
        }
    }

    #[test]
    fn table_is_deterministic_and_in_domain() {
        let c = cfg();
        let (cust, grp, qty, build) = c.table();
        let (cust2, ..) = c.table();
        assert_eq!(cust, cust2);
        let domain = 1u64 << c.width;
        assert!(cust.iter().all(|&v| v < domain));
        assert!(grp.iter().all(|&v| v < c.groups));
        assert!(qty.iter().all(|&v| v < domain));
        assert_eq!(build.len(), c.build_keys);
        // the build side holds even keys only, so roughly half the
        // probe rows find a partner
        assert!(build.iter().all(|&k| k % 2 == 0));
    }

    #[test]
    fn puma_cells_run_in_dram_and_verify() {
        let rs = run(scheme(), &cfg(), AllocatorKind::Puma(FitPolicy::WorstFit))
            .unwrap();
        assert_eq!(rs.len(), 6, "3 flat + 3 sharded cells");
        for r in &rs {
            assert!(
                r.pud_row_fraction() > 0.9,
                "{} S={}: got {}",
                r.shape,
                r.shards,
                r.pud_row_fraction()
            );
            assert!(r.matches > 0, "{}: empty selection", r.shape);
            assert!(r.agg > 0, "{}: empty aggregate", r.shape);
            assert!(r.host_ns_per_elem > 0.0);
            assert!(r.batches >= 1);
        }
        let tk = rs.iter().find(|r| r.shape == "top_k").unwrap();
        assert!(tk.rounds >= 1 && tk.rounds <= tk.width as usize);
        // ties at the threshold are all selected, so >= k but far
        // from the whole table
        assert!(tk.matches >= cfg().k && tk.matches < cfg().rows as u64 / 2);
        // group-by covers every row when the groups span the key space
        let gb = rs.iter().find(|r| r.shape == "group_by").unwrap();
        assert_eq!(gb.matches, cfg().rows as u64);
    }

    #[test]
    fn malloc_cells_fall_back_but_stay_correct() {
        let c = QueriesConfig {
            shards: 0,
            ..cfg()
        };
        let rs = run(scheme(), &c, AllocatorKind::Malloc).unwrap();
        assert_eq!(rs.len(), 3);
        for r in &rs {
            assert!(
                r.pud_row_fraction() < 0.5,
                "{}: got {}",
                r.shape,
                r.pud_row_fraction()
            );
            assert!(r.matches > 0);
        }
    }

    #[test]
    fn warm_repeat_hits_both_caches() {
        let c = QueriesConfig {
            shards: 0,
            ..cfg()
        };
        let mut sys = System::boot(SystemConfig {
            scheme: scheme(),
            huge_pages: c.huge_pages,
            churn_rounds: c.churn_rounds,
            seed: c.seed,
            artifacts: None,
            ..Default::default()
        })
        .unwrap();
        let pid = sys.spawn();
        let kind = AllocatorKind::Puma(FitPolicy::WorstFit);
        let mut alloc = kind.build(&mut sys, c.puma_pages).unwrap();
        let mut pools = ShardedScratch::new();
        let cold = run_cell_semi_join(
            &mut sys, alloc.as_mut(), pid, "puma", &c, &mut pools,
        )
        .unwrap();
        assert!(cold.col_misses >= 1 && cold.compiles >= 1);
        let warm = run_cell_semi_join(
            &mut sys, alloc.as_mut(), pid, "puma", &c, &mut pools,
        )
        .unwrap();
        assert_eq!(warm.col_misses, 0, "warm repeat rebuilds no column");
        assert_eq!(warm.compiles, 0, "warm repeat compiles nothing");
        assert_eq!(warm.pool_leases, 0, "warm repeat leases nothing");
        assert_eq!(warm.agg, cold.agg);
        assert_eq!(warm.matches, cold.matches);
        sys.trim_pools(alloc.as_mut(), pid, &mut pools, 0).unwrap();
        sys.flush_columns(alloc.as_mut(), pid).unwrap();
    }

    #[test]
    fn sweep_puma_beats_malloc_per_shape() {
        let c = QueriesConfig {
            rows: 8 * 1024,
            k: 512,
            shards: 0,
            churn_rounds: 300,
            ..Default::default()
        };
        let rs = sweep(
            &scheme(),
            &c,
            &[
                AllocatorKind::Malloc,
                AllocatorKind::Puma(FitPolicy::WorstFit),
            ],
        )
        .unwrap();
        assert_eq!(rs.len(), 6);
        for shape in ["semi_join", "group_by", "top_k"] {
            let puma = rs
                .iter()
                .find(|r| r.allocator == "puma" && r.shape == shape)
                .unwrap();
            let malloc = rs
                .iter()
                .find(|r| r.allocator == "malloc" && r.shape == shape)
                .unwrap();
            assert!(
                puma.pud_row_fraction() > malloc.pud_row_fraction(),
                "{shape}: puma {} vs malloc {}",
                puma.pud_row_fraction(),
                malloc.pud_row_fraction()
            );
            assert_eq!(puma.agg, malloc.agg, "results are placement-independent");
            assert_eq!(puma.matches, malloc.matches);
        }
    }
}
