//! Allocation-size sweeps — the driver behind Figure 2 and the §1
//! motivation study.
//!
//! The paper sweeps "from 2000 bits to 6 Mb". We interpret the range
//! as bit-denominated (2000 b = 250 B up to 6 Mb = 768 KiB) and sweep
//! log-spaced sizes across it (plus a few beyond, to show saturation).

use anyhow::Result;

use crate::coordinator::system::{System, SystemConfig};
use crate::dram::address::InterleaveScheme;

use super::microbench::{self, AllocatorKind, Micro, MicrobenchResult};

/// The paper's sweep sizes in bytes (2000 bits ... 6 Mb, log-spaced).
pub fn paper_sizes() -> Vec<u64> {
    vec![
        250,        // 2000 bits
        1 << 10,    // 8 Kb
        4 << 10,    // 32 Kb
        16 << 10,   // 128 Kb
        64 << 10,   // 512 Kb
        192 << 10,  // 1.5 Mb
        384 << 10,  // 3 Mb
        768 << 10,  // 6 Mb
    ]
}

/// One sweep cell result.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub result: MicrobenchResult,
    /// malloc-baseline simulated ns for the same (micro, size) cell.
    pub baseline_ns: f64,
}

impl SweepCell {
    /// Speedup over the malloc baseline (Figure 2's y-axis).
    pub fn speedup(&self) -> f64 {
        self.baseline_ns / self.result.sim_ns
    }
}

/// Sweep configuration.
pub struct SweepConfig {
    pub scheme: InterleaveScheme,
    pub sizes: Vec<u64>,
    pub reps: u32,
    pub huge_pages: usize,
    pub puma_pages: usize,
    pub churn_rounds: usize,
    pub seed: u64,
    /// Artifacts dir: Some => run fallback through XLA.
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            scheme: InterleaveScheme::row_major(Default::default()),
            sizes: paper_sizes(),
            // arrays are allocated once and used across the workload,
            // as in the paper's micro-benchmarks; 16 ops amortize the
            // allocation path realistically
            reps: 16,
            huge_pages: 256,
            puma_pages: 64,
            churn_rounds: 20_000,
            seed: 0xF16,
            artifacts: None,
        }
    }
}

fn fresh_system(cfg: &SweepConfig) -> Result<System> {
    System::boot(SystemConfig {
        scheme: cfg.scheme.clone(),
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: cfg.artifacts.clone(),
        ..Default::default()
    })
}

/// Run `micro` for `kind` across the sweep's sizes, pairing each cell
/// with the malloc baseline on an identical fresh machine.
pub fn run_micro_sweep(
    cfg: &SweepConfig,
    kind: AllocatorKind,
    micro: Micro,
) -> Result<Vec<SweepCell>> {
    let mut cells = Vec::with_capacity(cfg.sizes.len());
    for &size in &cfg.sizes {
        let result = {
            let mut sys = fresh_system(cfg)?;
            microbench::run(
                &mut sys,
                kind,
                micro,
                size,
                cfg.reps,
                cfg.puma_pages,
                false,
                cfg.seed ^ size,
            )?
        };
        let baseline = {
            let mut sys = fresh_system(cfg)?;
            microbench::run(
                &mut sys,
                AllocatorKind::Malloc,
                micro,
                size,
                cfg.reps,
                0,
                false,
                cfg.seed ^ size,
            )?
        };
        cells.push(SweepCell {
            result,
            baseline_ns: baseline.sim_ns,
        });
    }
    Ok(cells)
}

/// Motivation study (E1): fraction of PUD-executable rows per
/// allocator per size, for the `aand` micro-benchmark (the paper's
/// operand-heaviest case).
pub fn run_motivation(
    cfg: &SweepConfig,
    kinds: &[AllocatorKind],
) -> Result<Vec<(AllocatorKind, u64, f64)>> {
    let mut rows = Vec::new();
    for &kind in kinds {
        for &size in &cfg.sizes {
            let mut sys = fresh_system(cfg)?;
            let r = microbench::run(
                &mut sys,
                kind,
                Micro::Aand,
                size,
                1,
                cfg.puma_pages,
                false,
                cfg.seed ^ size,
            )?;
            rows.push((kind, size, r.pud_fraction()));
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::FitPolicy;
    use crate::dram::geometry::DramGeometry;

    fn small_cfg() -> SweepConfig {
        SweepConfig {
            scheme: InterleaveScheme::row_major(DramGeometry::small()),
            sizes: vec![250, 16 << 10, 256 << 10],
            reps: 1,
            huge_pages: 12,
            puma_pages: 8,
            churn_rounds: 2_000,
            seed: 5,
            artifacts: None,
        }
    }

    #[test]
    fn paper_sizes_span_the_paper_range() {
        let s = paper_sizes();
        assert_eq!(*s.first().unwrap(), 250);
        assert_eq!(*s.last().unwrap(), 768 << 10);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn puma_speedup_grows_with_size() {
        let cfg = small_cfg();
        let cells =
            run_micro_sweep(&cfg, AllocatorKind::Puma(FitPolicy::WorstFit), Micro::Copy)
                .unwrap();
        assert_eq!(cells.len(), 3);
        let speedups: Vec<f64> = cells.iter().map(|c| c.speedup()).collect();
        // largest size beats smallest (the paper's second observation)
        assert!(
            speedups[2] > speedups[0],
            "speedups should grow: {speedups:?}"
        );
        // and PUMA wins at the top size
        assert!(speedups[2] > 1.5, "speedups: {speedups:?}");
    }

    #[test]
    fn motivation_orders_allocators() {
        let cfg = small_cfg();
        let rows = run_motivation(
            &cfg,
            &[
                AllocatorKind::Malloc,
                AllocatorKind::Puma(FitPolicy::WorstFit),
            ],
        )
        .unwrap();
        let malloc_max = rows
            .iter()
            .filter(|(k, _, _)| *k == AllocatorKind::Malloc)
            .map(|(_, _, f)| *f)
            .fold(0.0, f64::max);
        let puma_min = rows
            .iter()
            .filter(|(k, _, _)| matches!(k, AllocatorKind::Puma(_)))
            .filter(|(_, s, _)| *s >= 16 << 10)
            .map(|(_, _, f)| *f)
            .fold(1.0, f64::min);
        assert!(malloc_max < 0.05, "malloc should be ~0%: {malloc_max}");
        assert!(puma_min > 0.9, "puma should be ~100%: {puma_min}");
    }
}
