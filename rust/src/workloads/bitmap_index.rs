//! Bitmap-index query workload.
//!
//! The database scenario motivating Ambit-class PUD: a table keeps one
//! bitmap per attribute value; a conjunctive query ANDs the relevant
//! bitmaps and counts the survivors. With PUMA placement the ANDs run
//! in-DRAM; with malloc placement every AND streams to the CPU.
//!
//! Used by examples/bitmap_index.rs and examples/database_scan.rs.

use anyhow::Result;

use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::util::rng::Pcg64;

/// A bitmap index over `rows` table rows with one bitmap per value.
pub struct BitmapIndex {
    pub pid: Pid,
    /// (value label, VA of its bitmap)
    pub bitmaps: Vec<(String, u64)>,
    /// scratch destination bitmap for query evaluation
    pub scratch: u64,
    /// bitmap length in bytes
    pub len: u64,
    /// ground-truth bits for verification, one Vec<u8> per bitmap
    truth: Vec<Vec<u8>>,
}

impl BitmapIndex {
    /// Build an index: `values` bitmaps over `table_rows` rows, each
    /// bit set with probability `density`. The first bitmap is
    /// allocated with `alloc` and the rest are hint-aligned to it.
    pub fn build(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        values: &[&str],
        table_rows: u64,
        density: f64,
        seed: u64,
    ) -> Result<BitmapIndex> {
        let len = crate::pud::arith::plane_bytes(table_rows as usize);
        let mut rng = Pcg64::new(seed);
        let mut bitmaps = Vec::with_capacity(values.len());
        let mut truth = Vec::with_capacity(values.len());
        let mut first = None;
        for v in values {
            let va = match first {
                None => {
                    let va = sys.alloc(alloc, pid, len)?;
                    first = Some(va);
                    va
                }
                Some(f) => sys.alloc_align(alloc, pid, len, f)?,
            };
            let mut bits = vec![0u8; len as usize];
            for byte in bits.iter_mut() {
                for bit in 0..8 {
                    if rng.chance(density) {
                        *byte |= 1 << bit;
                    }
                }
            }
            sys.write_virt(pid, va, &bits)?;
            bitmaps.push((v.to_string(), va));
            truth.push(bits);
        }
        let scratch = sys.alloc_align(alloc, pid, len, first.expect("values nonempty"))?;
        Ok(BitmapIndex {
            pid,
            bitmaps,
            scratch,
            len,
            truth,
        })
    }

    /// Evaluate a conjunctive query over bitmap indices `terms`
    /// (indices into `self.bitmaps`): AND them into the scratch
    /// bitmap. Returns (simulated ns, matching row count).
    pub fn query_and(
        &self,
        sys: &mut System,
        terms: &[usize],
    ) -> Result<(f64, u64)> {
        anyhow::ensure!(terms.len() >= 2, "need at least two terms");
        let mut ns = 0.0;
        // scratch = t0 AND t1
        ns += sys.submit(
            self.pid,
            &BulkRequest::new(
                PudOp::And,
                self.scratch,
                vec![self.bitmaps[terms[0]].1, self.bitmaps[terms[1]].1],
                self.len,
            ),
        )?;
        // scratch &= tk
        for &t in &terms[2..] {
            ns += sys.submit(
                self.pid,
                &BulkRequest::new(
                    PudOp::And,
                    self.scratch,
                    vec![self.scratch, self.bitmaps[t].1],
                    self.len,
                ),
            )?;
        }
        let out = sys.read_virt(self.pid, self.scratch, self.len)?;
        let count: u64 = out.iter().map(|b| b.count_ones() as u64).sum();
        Ok((ns, count))
    }

    /// Ground-truth count for the same query (host-side reference).
    pub fn expected_count(&self, terms: &[usize]) -> u64 {
        let mut acc = self.truth[terms[0]].clone();
        for &t in &terms[1..] {
            for (a, b) in acc.iter_mut().zip(&self.truth[t]) {
                *a &= *b;
            }
        }
        acc.iter().map(|b| b.count_ones() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::coordinator::system::SystemConfig;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;

    fn sys() -> System {
        let scheme = InterleaveScheme::row_major(DramGeometry::small());
        System::boot(SystemConfig {
            scheme,
            huge_pages: 16,
            churn_rounds: 1_000,
            seed: 6,
            artifacts: None,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn query_counts_match_ground_truth() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 10).unwrap();
        let idx = BitmapIndex::build(
            &mut sys,
            &mut puma,
            pid,
            &["red", "large", "recent"],
            512 * 1024, // bits -> 64 KiB bitmaps
            0.3,
            99,
        )
        .unwrap();
        let (ns, count) = idx.query_and(&mut sys, &[0, 1, 2]).unwrap();
        assert!(ns > 0.0);
        assert_eq!(count, idx.expected_count(&[0, 1, 2]));
        // ~0.3^3 density
        let frac = count as f64 / (512.0 * 1024.0);
        assert!((frac - 0.027).abs() < 0.005, "density {frac}");
        // PUMA placement => queries run in-DRAM
        assert!(sys.coord.stats.pud_row_fraction() > 0.9);
    }

    #[test]
    fn two_term_query_minimum() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let idx =
            BitmapIndex::build(&mut sys, &mut puma, pid, &["a", "b"], 65536, 0.5, 1)
                .unwrap();
        assert!(idx.query_and(&mut sys, &[0]).is_err());
        let (_, count) = idx.query_and(&mut sys, &[0, 1]).unwrap();
        assert_eq!(count, idx.expected_count(&[0, 1]));
    }
}
