//! Workload generators.
//!
//! * [`microbench`] — the paper's three micro-benchmarks (`*-zero`,
//!   `*-copy`, `*-aand`) over any allocator.
//! * [`sweep`] — allocation-size sweeps (Figure 2 / motivation study).
//! * [`trace`] — record/replay allocation+op traces for multi-process
//!   fragmentation stress.
//! * [`churn`] — the multi-tenant aging driver: pool pressure,
//!   co-location decay, and the reclamation/compaction lifecycle
//!   (DESIGN.md §8).
//! * [`bitmap_index`] — bitmap-index query workload (the database
//!   scenario motivating Ambit-class PUD).
//! * [`setops`] — set algebra over bit-vector sets (SISA-like), now
//!   compiled through `pud::compiler`.
//! * [`filter`] — multi-clause predicate filter over bitmap columns:
//!   compiled single-batch execution vs hand-issued sequential ops.
//! * [`analytics`] — filter-then-sum aggregate over a vertical
//!   (bit-transposed) column table: compiled `pud::arith` kernels vs
//!   the CPU-fallback path, swept over bit-widths and allocators.
//! * [`queries`] — the analytics query engine end-to-end: bitmap
//!   semi-join, single-batch group-by aggregation, and top-k
//!   threshold bisection over a TPC-H-flavored micro-table, verified
//!   against scalar oracles and swept over allocators.
//! * [`serve`] — the multi-tenant serving study: twin gateways drain
//!   identical mixed traffic (filter/analytics/query/churn tenants)
//!   under the DRR fairness scheduler vs back-to-back, verifying
//!   byte-identical results while comparing tenant-completion
//!   percentiles (DESIGN.md §15).

pub mod analytics;
pub mod bitmap_index;
pub mod churn;
pub mod filter;
pub mod microbench;
pub mod queries;
pub mod serve;
pub mod setops;
pub mod sweep;
pub mod trace;

pub use churn::{ChurnConfig, ChurnResult, EpochSample, TenantLatency};
pub use microbench::{AllocatorKind, Micro, MicrobenchResult};
