//! The paper's three micro-benchmarks over any allocator.
//!
//! * `*-zero` — initialize an array with zeros (RowClone zero-init).
//! * `*-copy` — copy one array into another (RowClone copy).
//! * `*-aand` — `C[i] = A[i] AND B[i]` (Ambit).
//!
//! Allocation protocol (paper §2): the first operand uses `pim_alloc`
//! (plain `alloc` on baselines); subsequent operands use
//! `pim_alloc_align` with the first operand as the hint (baselines
//! ignore the hint). Simulated time charges both the allocation path
//! and the operation stream.

use anyhow::Result;

use crate::alloc::hugealloc::HugeAlloc;
use crate::alloc::mallocsim::MallocSim;
use crate::alloc::memalign::MemalignSim;
use crate::alloc::puma::{FitPolicy, PumaAlloc};
use crate::alloc::traits::{AllocStats, Allocator};
use crate::coordinator::system::System;
use crate::coordinator::CoordStats;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::util::rng::Pcg64;

/// Which micro-benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Micro {
    Zero,
    Copy,
    Aand,
}

impl Micro {
    pub const ALL: [Micro; 3] = [Micro::Zero, Micro::Copy, Micro::Aand];

    pub fn name(&self) -> &'static str {
        match self {
            Micro::Zero => "zero",
            Micro::Copy => "copy",
            Micro::Aand => "aand",
        }
    }

    /// Number of operand arrays (dst included).
    pub fn operands(&self) -> usize {
        match self {
            Micro::Zero => 1,
            Micro::Copy => 2,
            Micro::Aand => 3,
        }
    }

    fn op(&self) -> PudOp {
        match self {
            Micro::Zero => PudOp::Zero,
            Micro::Copy => PudOp::Copy,
            Micro::Aand => PudOp::And,
        }
    }
}

/// Allocator selection for sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocatorKind {
    Malloc,
    Memalign,
    HugePages,
    Puma(FitPolicy),
}

impl AllocatorKind {
    pub const BASELINES: [AllocatorKind; 3] = [
        AllocatorKind::Malloc,
        AllocatorKind::Memalign,
        AllocatorKind::HugePages,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AllocatorKind::Malloc => "malloc",
            AllocatorKind::Memalign => "posix_memalign",
            AllocatorKind::HugePages => "hugepages",
            AllocatorKind::Puma(FitPolicy::WorstFit) => "puma",
            AllocatorKind::Puma(FitPolicy::BestFit) => "puma-bestfit",
            AllocatorKind::Puma(FitPolicy::FirstFit) => "puma-firstfit",
        }
    }

    /// Instantiate; PUMA pre-allocates `puma_pages` huge pages.
    pub fn build(
        &self,
        sys: &mut System,
        puma_pages: usize,
    ) -> Result<Box<dyn Allocator>> {
        let row = sys.os.scheme.geometry.row_bytes as u64;
        Ok(match self {
            AllocatorKind::Malloc => Box::new(MallocSim::new()),
            AllocatorKind::Memalign => Box::new(MemalignSim::new(row)),
            AllocatorKind::HugePages => Box::new(HugeAlloc::new(row)),
            AllocatorKind::Puma(policy) => {
                let mut p = PumaAlloc::new(row, *policy);
                p.pim_preallocate(&mut sys.os, puma_pages)?;
                Box::new(p)
            }
        })
    }
}

/// Result of one micro-benchmark configuration.
#[derive(Debug, Clone)]
pub struct MicrobenchResult {
    pub micro: Micro,
    pub allocator: &'static str,
    pub size: u64,
    pub reps: u32,
    pub coord: CoordStats,
    pub alloc: AllocStats,
    /// Total simulated ns: allocation + operations.
    pub sim_ns: f64,
}

impl MicrobenchResult {
    pub fn pud_fraction(&self) -> f64 {
        self.coord.pud_row_fraction()
    }
}

/// Run one micro-benchmark: allocate operands with `kind`, run `reps`
/// bulk ops of `size` bytes, optionally verify the memory image.
pub fn run(
    sys: &mut System,
    kind: AllocatorKind,
    micro: Micro,
    size: u64,
    reps: u32,
    puma_pages: usize,
    verify: bool,
    seed: u64,
) -> Result<MicrobenchResult> {
    run_inner(sys, kind, micro, size, reps, puma_pages, verify, seed, false)
}

/// As [`run`], but submits all `reps` operations as one batch through
/// the plan/schedule/execute pipeline. Memory image and stats totals
/// are identical to the serial path; extent translations are cached
/// and control overheads amortized.
pub fn run_batched(
    sys: &mut System,
    kind: AllocatorKind,
    micro: Micro,
    size: u64,
    reps: u32,
    puma_pages: usize,
    verify: bool,
    seed: u64,
) -> Result<MicrobenchResult> {
    run_inner(sys, kind, micro, size, reps, puma_pages, verify, seed, true)
}

#[allow(clippy::too_many_arguments)]
fn run_inner(
    sys: &mut System,
    kind: AllocatorKind,
    micro: Micro,
    size: u64,
    reps: u32,
    puma_pages: usize,
    verify: bool,
    seed: u64,
    batched: bool,
) -> Result<MicrobenchResult> {
    let pid = sys.spawn();
    let mut alloc = kind.build(sys, puma_pages)?;
    // pim_preallocate is boot-time setup (the huge-page pool analogue
    // on the baseline side is likewise reserved at boot and not
    // charged); measure allocation costs from here on.
    let alloc_base_ns = alloc.stats().alloc_ns;
    let stats_before = sys.coord.stats.clone();

    // --- allocation phase (hint-chained, as the paper's API intends)
    let n_ops = micro.operands();
    let mut vas = Vec::with_capacity(n_ops);
    let first = sys.alloc(alloc.as_mut(), pid, size)?;
    vas.push(first);
    for _ in 1..n_ops {
        vas.push(sys.alloc_align(alloc.as_mut(), pid, size, first)?);
    }

    // --- seed the sources with deterministic data
    let mut rng = Pcg64::new(seed);
    let mut expected: Option<Vec<u8>> = None;
    match micro {
        Micro::Zero => {
            // destination starts dirty so zeroing is observable
            let dirty = vec![0xEEu8; size as usize];
            sys.write_virt(pid, vas[0], &dirty)?;
            if verify {
                expected = Some(vec![0u8; size as usize]);
            }
        }
        Micro::Copy => {
            let mut a = vec![0u8; size as usize];
            rng.fill_bytes(&mut a);
            sys.write_virt(pid, vas[0], &a)?;
            if verify {
                expected = Some(a);
            }
        }
        Micro::Aand => {
            let mut a = vec![0u8; size as usize];
            let mut b = vec![0u8; size as usize];
            rng.fill_bytes(&mut a);
            rng.fill_bytes(&mut b);
            sys.write_virt(pid, vas[0], &a)?;
            sys.write_virt(pid, vas[1], &b)?;
            if verify {
                expected = Some(a.iter().zip(&b).map(|(x, y)| x & y).collect());
            }
        }
    }

    // --- operation phase
    let (dst, srcs) = match micro {
        Micro::Zero => (vas[0], vec![]),
        Micro::Copy => (vas[1], vec![vas[0]]),
        Micro::Aand => (vas[2], vec![vas[0], vas[1]]),
    };
    let req = BulkRequest::new(micro.op(), dst, srcs, size);
    let mut op_ns = 0.0;
    if batched {
        let reqs = vec![req.clone(); reps as usize];
        op_ns += sys.submit_batch(pid, &reqs)?.total_ns;
    } else {
        for _ in 0..reps {
            op_ns += sys.submit(pid, &req)?;
        }
    }

    if let Some(want) = expected {
        let got = sys.read_virt(pid, dst, size)?;
        anyhow::ensure!(
            got == want,
            "{}-{} functional mismatch (size {size})",
            kind.name(),
            micro.name()
        );
    }

    let coord = diff(&sys.coord.stats.clone(), &stats_before);
    let mut alloc_stats = alloc.stats();
    alloc_stats.alloc_ns -= alloc_base_ns;
    let sim_ns = alloc_stats.alloc_ns + op_ns;
    Ok(MicrobenchResult {
        micro,
        allocator: kind.name(),
        size,
        reps,
        coord,
        alloc: alloc_stats,
        sim_ns,
    })
}

fn diff(after: &CoordStats, before: &CoordStats) -> CoordStats {
    CoordStats {
        ops: after.ops - before.ops,
        ops_fully_pud: crate::util::stats::HitRate {
            hits: after.ops_fully_pud.hits - before.ops_fully_pud.hits,
            total: after.ops_fully_pud.total - before.ops_fully_pud.total,
        },
        pud_rows: after.pud_rows - before.pud_rows,
        fallback_rows: after.fallback_rows - before.fallback_rows,
        pud_bytes: after.pud_bytes - before.pud_bytes,
        fallback_bytes: after.fallback_bytes - before.fallback_bytes,
        pud_ns: after.pud_ns - before.pud_ns,
        fallback_ns: after.fallback_ns - before.fallback_ns,
        alloc_ns: after.alloc_ns - before.alloc_ns,
        xla_dispatches: after.xla_dispatches - before.xla_dispatches,
        xla_wall_ns: after.xla_wall_ns - before.xla_wall_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::system::SystemConfig;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;

    fn small_system() -> System {
        let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
        System::boot(SystemConfig {
            scheme,
            huge_pages: 12,
            churn_rounds: 3_000,
            seed: 1,
            artifacts: None,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn puma_aand_nearly_all_pud_and_correct() {
        let mut sys = small_system();
        let r = run(
            &mut sys,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            Micro::Aand,
            256 * 1024,
            2,
            8,
            true,
            42,
        )
        .unwrap();
        assert!(r.pud_fraction() > 0.95, "got {}", r.pud_fraction());
        assert!(r.sim_ns > 0.0);
    }

    #[test]
    fn malloc_aand_zero_pud_but_correct() {
        let mut sys = small_system();
        let r = run(
            &mut sys,
            AllocatorKind::Malloc,
            Micro::Aand,
            256 * 1024,
            1,
            0,
            true,
            42,
        )
        .unwrap();
        assert!(r.pud_fraction() < 0.05, "got {}", r.pud_fraction());
    }

    #[test]
    fn all_micros_all_allocators_verify() {
        for micro in Micro::ALL {
            for kind in [
                AllocatorKind::Malloc,
                AllocatorKind::Memalign,
                AllocatorKind::HugePages,
                AllocatorKind::Puma(FitPolicy::WorstFit),
            ] {
                let mut sys = small_system();
                let r = run(&mut sys, kind, micro, 64 * 1024, 1, 8, true, 7)
                    .unwrap_or_else(|e| {
                        panic!("{}-{} failed: {e}", kind.name(), micro.name())
                    });
                assert_eq!(r.coord.ops, 1);
            }
        }
    }

    #[test]
    fn batched_run_matches_serial() {
        let args = (Micro::Aand, 128 * 1024u64, 3u32, 8usize, true, 11u64);
        let mut s1 = small_system();
        let serial = run(
            &mut s1,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            args.0,
            args.1,
            args.2,
            args.3,
            args.4,
            args.5,
        )
        .unwrap();
        let mut s2 = small_system();
        let batched = run_batched(
            &mut s2,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            args.0,
            args.1,
            args.2,
            args.3,
            args.4,
            args.5,
        )
        .unwrap();
        assert_eq!(serial.coord, batched.coord, "stats totals must match");
        assert!((serial.sim_ns - batched.sim_ns).abs() < 1e-6);
        // identical reps write-conflict on the destination, so the
        // scheduler must serialize them into one wave each
        assert_eq!(s2.coord.pipeline.waves, args.2 as u64);
        // repeated submissions over stable mappings hit the cache
        assert!(s2.coord.pipeline.extent_cache.hits > 0);
    }

    #[test]
    fn puma_beats_malloc_in_sim_time_at_large_sizes() {
        let size = 1 << 20;
        let mut s1 = small_system();
        let puma = run(
            &mut s1,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            Micro::Copy,
            size,
            4,
            10,
            false,
            3,
        )
        .unwrap();
        let mut s2 = small_system();
        let malloc = run(&mut s2, AllocatorKind::Malloc, Micro::Copy, size, 4, 0, false, 3)
            .unwrap();
        let speedup = malloc.sim_ns / puma.sim_ns;
        assert!(speedup > 2.0, "expected speedup > 2, got {speedup:.2}");
    }
}
