//! Report rendering: regenerates the paper's figures/tables as
//! markdown tables, ASCII charts, and CSV files.

use std::path::Path;

use anyhow::Result;

use crate::alloc::traits::AllocStats;
use crate::dram::energy::EnergyParams;
use crate::dram::timing::TimingParams;
use crate::pud::isa::PudOp;
use crate::pud::legality::CauseCounts;
use crate::util::csvio::Csv;
use crate::util::table::{fnum, Table};
use crate::util::units::{fmt_bytes, fmt_ns};
use crate::workloads::analytics::{AnalyticsResult, ShardedResult};
use crate::workloads::churn::ChurnResult;
use crate::workloads::filter::FilterResult;
use crate::workloads::microbench::{AllocatorKind, Micro};
use crate::workloads::queries::QueryResult;
use crate::workloads::serve::ServeResult;
use crate::workloads::sweep::SweepCell;

/// Render the Figure 2 reproduction: PUMA speedup over malloc, one
/// series per micro-benchmark, across allocation sizes.
pub fn figure2(
    series: &[(Micro, Vec<SweepCell>)],
    out_dir: Option<&Path>,
) -> Result<String> {
    let sizes: Vec<u64> = series
        .first()
        .map(|(_, cells)| cells.iter().map(|c| c.result.size).collect())
        .unwrap_or_default();
    let mut table = Table::new(
        std::iter::once("size".to_string())
            .chain(series.iter().map(|(m, _)| format!("{}-speedup", m.name())))
            .chain(series.iter().map(|(m, _)| format!("{}-pud%", m.name())))
            .collect::<Vec<String>>(),
    )
    .left(0);
    let mut csv = Csv::new(vec![
        "size_bytes",
        "micro",
        "allocator",
        "sim_ns",
        "baseline_ns",
        "speedup",
        "pud_fraction",
    ]);
    for (i, &size) in sizes.iter().enumerate() {
        let mut row = vec![fmt_bytes(size)];
        for (_, cells) in series {
            row.push(format!("{}x", fnum(cells[i].speedup())));
        }
        for (_, cells) in series {
            row.push(format!("{:.0}%", cells[i].result.pud_fraction() * 100.0));
        }
        table.row(row);
        for (m, cells) in series {
            let c = &cells[i];
            csv.row(vec![
                size.to_string(),
                m.name().to_string(),
                c.result.allocator.to_string(),
                format!("{:.1}", c.result.sim_ns),
                format!("{:.1}", c.baseline_ns),
                format!("{:.4}", c.speedup()),
                format!("{:.4}", c.result.pud_fraction()),
            ]);
        }
    }
    let chart = crate::util::chart::line_chart(
        &sizes.iter().map(|s| fmt_bytes(*s)).collect::<Vec<_>>(),
        &series
            .iter()
            .map(|(m, cells)| {
                (
                    format!("{}-speedup", m.name()),
                    cells.iter().map(|c| c.speedup()).collect(),
                )
            })
            .collect::<Vec<_>>(),
        12,
    );
    if let Some(dir) = out_dir {
        csv.write(dir.join("figure2.csv"))?;
    }
    Ok(format!(
        "## Figure 2 — PUMA speedup vs malloc (simulated time)\n\n{}\n{}",
        table.render(),
        chart
    ))
}

/// Render the §1 motivation study: PUD-executable fraction per
/// allocator per size.
pub fn motivation(
    rows: &[(AllocatorKind, u64, f64)],
    out_dir: Option<&Path>,
) -> Result<String> {
    // collect the size axis
    let mut sizes: Vec<u64> = rows.iter().map(|(_, s, _)| *s).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let mut kinds: Vec<AllocatorKind> = Vec::new();
    for (k, _, _) in rows {
        if !kinds.contains(k) {
            kinds.push(*k);
        }
    }
    let mut table = Table::new(
        std::iter::once("allocator".to_string())
            .chain(sizes.iter().map(|s| fmt_bytes(*s)))
            .collect::<Vec<String>>(),
    )
    .left(0);
    let mut csv = Csv::new(vec!["allocator", "size_bytes", "pud_fraction"]);
    for k in &kinds {
        let mut row = vec![k.name().to_string()];
        for s in &sizes {
            let frac = rows
                .iter()
                .find(|(rk, rs, _)| rk == k && rs == s)
                .map(|(_, _, f)| *f)
                .unwrap_or(0.0);
            row.push(format!("{:.0}%", frac * 100.0));
        }
        table.row(row);
    }
    for (k, s, f) in rows {
        csv.row(vec![
            k.name().to_string(),
            s.to_string(),
            format!("{f:.4}"),
        ]);
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("motivation.csv"))?;
    }
    Ok(format!(
        "## §1 motivation — PUD-executable operations per allocator\n\n{}",
        table.render()
    ))
}

/// Render the allocation-lifecycle counters of [`AllocStats`] — the
/// free-path/reclaim/compaction accounting added in DESIGN.md §8 —
/// for one or more allocators side by side.
pub fn alloc_lifecycle(entries: &[(&str, AllocStats)]) -> String {
    let mut table = Table::new(vec![
        "allocator",
        "allocs",
        "frees",
        "bytes-req",
        "bytes-freed",
        "pages-map",
        "pages-unmap",
        "reclaimed",
        "migrated",
        "occ%",
        "frag%",
    ])
    .left(0);
    for (name, s) in entries {
        table.row(vec![
            name.to_string(),
            s.allocs.to_string(),
            s.frees.to_string(),
            fmt_bytes(s.bytes_requested),
            fmt_bytes(s.bytes_freed),
            s.pages_mapped.to_string(),
            s.pages_unmapped.to_string(),
            s.pages_reclaimed.to_string(),
            s.regions_migrated.to_string(),
            format!("{:.0}%", s.pool_occupancy * 100.0),
            format!("{:.0}%", s.fragmentation * 100.0),
        ]);
    }
    table.render()
}

/// Render the churn-workload comparison: per-epoch lifecycle curves
/// for a compaction-off run and (optionally) a compaction-on run,
/// plus the steady-state summary. Writes `churn.csv` when `out_dir`
/// is given.
pub fn churn(
    off: &ChurnResult,
    on: Option<&ChurnResult>,
    out_dir: Option<&Path>,
) -> Result<String> {
    let runs: Vec<(&str, &ChurnResult)> = std::iter::once(("off", off))
        .chain(on.map(|r| ("on", r)))
        .collect();
    churn_runs(&runs, out_dir)
}

/// As [`churn`], with caller-chosen labels (the CLI's single-mode
/// rendering). The pairwise win/loss summary appears with exactly two
/// runs.
pub fn churn_runs(
    runs: &[(&str, &ChurnResult)],
    out_dir: Option<&Path>,
) -> Result<String> {
    let mut table = Table::new(vec![
        "epoch",
        "mode",
        "live",
        "op-pud%",
        "peak-occ%",
        "occ%",
        "frag%",
        "free",
        "migrated",
        "reclaimed",
    ])
    .left(1);
    let mut csv = Csv::new(vec![
        "mode",
        "epoch",
        "op_pud_fraction",
        "peak_occupancy",
        "pool_occupancy",
        "fragmentation",
        "free_regions",
        "regions_migrated_total",
        "pages_reclaimed_total",
        "op_ns",
        "compact_ns",
    ]);
    for (mode, r) in runs {
        for s in &r.samples {
            table.row(vec![
                s.epoch.to_string(),
                mode.to_string(),
                s.live_groups.to_string(),
                format!("{:.1}%", s.op_pud_fraction * 100.0),
                format!("{:.0}%", s.peak_occupancy * 100.0),
                format!("{:.0}%", s.pool_occupancy * 100.0),
                format!("{:.0}%", s.fragmentation * 100.0),
                s.free_regions.to_string(),
                s.regions_migrated_total.to_string(),
                s.pages_reclaimed_total.to_string(),
            ]);
            csv.row(vec![
                mode.to_string(),
                s.epoch.to_string(),
                format!("{:.6}", s.op_pud_fraction),
                format!("{:.6}", s.peak_occupancy),
                format!("{:.6}", s.pool_occupancy),
                format!("{:.6}", s.fragmentation),
                s.free_regions.to_string(),
                s.regions_migrated_total.to_string(),
                s.pages_reclaimed_total.to_string(),
                format!("{:.1}", s.op_ns),
                format!("{:.1}", s.compact_ns),
            ]);
        }
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("churn.csv"))?;
    }
    let mut summary = String::new();
    for (mode, r) in runs {
        summary.push_str(&format!(
            "compaction {mode:>3}: steady-state PUD-row fraction {:.3}, \
             {} page(s) returned to the boot pool, final occupancy {:.0}%, \
             workload {}\n",
            r.steady_state_pud_fraction,
            r.pages_returned,
            r.final_occupancy * 100.0,
            fmt_ns(r.samples.iter().map(|s| s.op_ns).sum()),
        ));
    }
    if let [(_, base), (_, cmp)] = runs {
        summary.push_str(&format!(
            "compaction wins {:+.1} PUD-row points at steady state and \
             returns {} more page(s); migration cost {}\n",
            (cmp.steady_state_pud_fraction - base.steady_state_pud_fraction)
                * 100.0,
            cmp.pages_returned as i64 - base.pages_returned as i64,
            fmt_ns(cmp.samples.iter().map(|s| s.compact_ns).sum()),
        ));
    }
    // per-tenant latency percentiles, read back from the coordinator's
    // metrics registry (churn/t{i}/alloc_ns, churn/t{i}/op_ns)
    let mut lat = Table::new(vec![
        "mode", "tenant", "allocs", "alloc-p50", "alloc-p99", "ops",
        "op-p50", "op-p99",
    ])
    .left(0)
    .left(1);
    for (mode, r) in runs {
        for t in &r.tenant_latency {
            lat.row(vec![
                mode.to_string(),
                format!("t{}", t.tenant),
                t.allocs.to_string(),
                fmt_ns(t.alloc_p50_ns as f64),
                fmt_ns(t.alloc_p99_ns as f64),
                t.ops.to_string(),
                fmt_ns(t.op_p50_ns as f64),
                fmt_ns(t.op_p99_ns as f64),
            ]);
        }
    }
    let latency = if lat.is_empty() {
        String::new()
    } else {
        format!(
            "per-tenant latency (simulated, registry p50/p99):\n\n{}\n",
            lat.render()
        )
    };
    let lifecycle = alloc_lifecycle(
        &runs
            .iter()
            .map(|(mode, r)| {
                (
                    if *mode == "on" {
                        "puma (compact)"
                    } else {
                        "puma (no compact)"
                    },
                    r.alloc,
                )
            })
            .collect::<Vec<_>>(),
    );
    Ok(format!(
        "## Churn — allocation lifecycle under multi-tenant aging\n\n{}\n{}\n{}{}",
        table.render(),
        summary,
        latency,
        lifecycle
    ))
}

/// Render the per-op cost table: one row per [`PudOp::ALL`] entry,
/// with arity and the AAP/TRA/ns/nJ per-row charges all derived from
/// the single cost table on [`PudOp`] — the place to see that
/// composite XOR is priced as its 7-AAP/3-TRA sequence (never a
/// single TRA) consistently across timing, energy, and the scheduler.
pub fn op_costs(t: &TimingParams, e: &EnergyParams) -> String {
    let mut table = Table::new(vec![
        "op",
        "arity",
        "aaps/row",
        "tras/row",
        "ns/row",
        "nJ/row",
    ])
    .left(0);
    for op in PudOp::ALL {
        table.row(vec![
            op.to_string(),
            op.arity().to_string(),
            op.aaps_per_row().to_string(),
            op.tras_per_row().to_string(),
            format!("{:.0}", op.pud_row_ns(t)),
            format!("{:.1}", op.pud_row_nj(e)),
        ]);
    }
    table.render()
}

/// Render the predicate-filter comparison: compiled single-batch
/// execution vs hand-issued sequential lowering, per allocator per
/// clause count. Writes `filter.csv` when `out_dir` is given.
pub fn filter(results: &[FilterResult], out_dir: Option<&Path>) -> Result<String> {
    let mut table = Table::new(vec![
        "allocator",
        "clauses",
        "cols",
        "ops",
        "nots",
        "scratch",
        "cse",
        "waves",
        "pud%",
        "hand-pud%",
        "speedup",
    ])
    .left(0);
    let mut csv = Csv::new(vec![
        "allocator",
        "clauses",
        "columns",
        "rows",
        "ops",
        "not_ops",
        "scratch_slots",
        "spills",
        "cse_hits",
        "waves",
        "compiled_pud_fraction",
        "hand_pud_fraction",
        "compiled_sim_ns",
        "compiled_elapsed_ns",
        "hand_ns",
        "speedup",
        "matches",
    ]);
    for r in results {
        table.row(vec![
            r.allocator.to_string(),
            r.clauses.to_string(),
            r.columns.to_string(),
            r.compile.ops.to_string(),
            r.compile.not_ops.to_string(),
            r.compile.scratch_slots.to_string(),
            r.compile.cse_hits.to_string(),
            r.waves.to_string(),
            format!("{:.0}%", r.compiled_pud_fraction * 100.0),
            format!("{:.0}%", r.hand_pud_fraction * 100.0),
            format!("{}x", fnum(r.speedup())),
        ]);
        csv.row(vec![
            r.allocator.to_string(),
            r.clauses.to_string(),
            r.columns.to_string(),
            r.rows.to_string(),
            r.compile.ops.to_string(),
            r.compile.not_ops.to_string(),
            r.compile.scratch_slots.to_string(),
            r.compile.spills.to_string(),
            r.compile.cse_hits.to_string(),
            r.waves.to_string(),
            format!("{:.6}", r.compiled_pud_fraction),
            format!("{:.6}", r.hand_pud_fraction),
            format!("{:.1}", r.compiled_ns),
            format!("{:.1}", r.elapsed_ns),
            format!("{:.1}", r.hand_ns),
            format!("{:.4}", r.speedup()),
            r.matches.to_string(),
        ]);
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("filter.csv"))?;
    }
    Ok(format!(
        "## Filter — compiled expression batches vs hand-issued ops\n\n{}",
        table.render()
    ))
}

/// Compact per-cause fallback attribution for the report tables:
/// `-` when every row ran in-DRAM, otherwise the non-zero causes
/// (`mis`=misaligned, `xsub`=cross-subarray, `rsv`=reserved row,
/// `frag`=fragmented).
fn fmt_causes(c: &CauseCounts) -> String {
    if c.total() == 0 {
        return "-".to_string();
    }
    let mut parts = Vec::new();
    if c.misaligned > 0 {
        parts.push(format!("mis:{}", c.misaligned));
    }
    if c.cross_subarray > 0 {
        parts.push(format!("xsub:{}", c.cross_subarray));
    }
    if c.reserved > 0 {
        parts.push(format!("rsv:{}", c.reserved));
    }
    if c.fragmented > 0 {
        parts.push(format!("frag:{}", c.fragmented));
    }
    parts.join(" ")
}

/// Render the analytics (filter-then-sum) sweep: one row per
/// allocator x bit-width cell, compiled vertical-arithmetic execution
/// with its W-bit op-cost accounting. Writes `analytics.csv` when
/// `out_dir` is given.
pub fn analytics(
    results: &[AnalyticsResult],
    out_dir: Option<&Path>,
) -> Result<String> {
    let mut table = Table::new(vec![
        "allocator",
        "width",
        "ops",
        "scratch",
        "folds",
        "waves",
        "aaps/elem",
        "pud%",
        "fb causes",
        "host ns/elem",
        "col h/m",
        "matches",
        "sum",
    ])
    .left(0);
    let mut csv = Csv::new(vec![
        "allocator",
        "width",
        "elems",
        "threshold",
        "ops",
        "scratch_slots",
        "spills",
        "folds",
        "cse_hits",
        "waves",
        "aaps_per_elem",
        "pud_row_fraction",
        "sim_ns",
        "elapsed_sim_ns",
        "host_ns_per_elem",
        "col_hits",
        "col_misses",
        "pool_leases",
        "matches",
        "sum",
        "pool_high_water",
        "fb_misaligned",
        "fb_cross_subarray",
        "fb_reserved",
        "fb_fragmented",
    ]);
    for r in results {
        table.row(vec![
            r.allocator.to_string(),
            r.width.to_string(),
            r.compile.ops.to_string(),
            r.compile.scratch_slots.to_string(),
            r.compile.folds.to_string(),
            r.waves.to_string(),
            format!("{:.4}", r.aaps_per_elem),
            format!("{:.0}%", r.pud_row_fraction() * 100.0),
            fmt_causes(&r.fallback_causes),
            format!("{:.2}", r.host_ns_per_elem),
            format!("{}/{}", r.col_hits, r.col_misses),
            r.matches.to_string(),
            r.sum.to_string(),
        ]);
        csv.row(vec![
            r.allocator.to_string(),
            r.width.to_string(),
            r.elems.to_string(),
            r.threshold.to_string(),
            r.compile.ops.to_string(),
            r.compile.scratch_slots.to_string(),
            r.compile.spills.to_string(),
            r.compile.folds.to_string(),
            r.compile.cse_hits.to_string(),
            r.waves.to_string(),
            format!("{:.6}", r.aaps_per_elem),
            format!("{:.6}", r.pud_row_fraction()),
            format!("{:.1}", r.sim_ns),
            format!("{:.1}", r.elapsed_ns),
            format!("{:.4}", r.host_ns_per_elem),
            r.col_hits.to_string(),
            r.col_misses.to_string(),
            r.pool_leases.to_string(),
            r.matches.to_string(),
            r.sum.to_string(),
            r.pool_high_water.to_string(),
            r.fallback_causes.misaligned.to_string(),
            r.fallback_causes.cross_subarray.to_string(),
            r.fallback_causes.reserved.to_string(),
            r.fallback_causes.fragmented.to_string(),
        ]);
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("analytics.csv"))?;
    }
    Ok(format!(
        "## Analytics — filter-then-sum over a vertical column table\n\n{}",
        table.render()
    ))
}

/// Render the sharded-analytics scale sweep: one row per
/// allocator x width x shard-count cell; `speedup` is the cell's
/// bank-parallel makespan win over the same allocator+width's S = 1
/// cell. Writes `analytics_sharded.csv` when `out_dir` is given.
pub fn analytics_sharded(
    results: &[ShardedResult],
    out_dir: Option<&Path>,
) -> Result<String> {
    let mut table = Table::new(vec![
        "allocator",
        "width",
        "shards",
        "waves",
        "pud%",
        "fb causes",
        "elapsed",
        "speedup",
        "host ns/elem",
        "col h/m",
        "matches",
        "sum",
    ])
    .left(0);
    let mut csv = Csv::new(vec![
        "allocator",
        "width",
        "shards",
        "shard_count",
        "elems",
        "threshold",
        "ops",
        "compiles",
        "waves",
        "pud_row_fraction",
        "sim_ns",
        "elapsed_sim_ns",
        "speedup_vs_s1",
        "host_ns_per_elem",
        "col_hits",
        "col_misses",
        "pool_leases",
        "matches",
        "sum",
        "pool_high_water",
        "fb_misaligned",
        "fb_cross_subarray",
        "fb_reserved",
        "fb_fragmented",
    ]);
    let base_of = |r: &ShardedResult| -> Option<f64> {
        results
            .iter()
            .find(|b| {
                b.allocator == r.allocator && b.width == r.width && b.shards == 1
            })
            .map(|b| b.elapsed_ns)
    };
    for r in results {
        let speedup = base_of(r).map(|b| b / r.elapsed_ns.max(1e-9));
        let speedup_txt = speedup
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".to_string());
        table.row(vec![
            r.allocator.to_string(),
            r.width.to_string(),
            r.shard_count.to_string(),
            r.waves.to_string(),
            format!("{:.0}%", r.pud_row_fraction() * 100.0),
            fmt_causes(&r.fallback_causes),
            fmt_ns(r.elapsed_ns),
            speedup_txt,
            format!("{:.2}", r.host_ns_per_elem),
            format!("{}/{}", r.col_hits, r.col_misses),
            r.matches.to_string(),
            r.sum.to_string(),
        ]);
        csv.row(vec![
            r.allocator.to_string(),
            r.width.to_string(),
            r.shards.to_string(),
            r.shard_count.to_string(),
            r.elems.to_string(),
            r.threshold.to_string(),
            r.compile.ops.to_string(),
            r.compile.compiles.to_string(),
            r.waves.to_string(),
            format!("{:.6}", r.pud_row_fraction()),
            format!("{:.1}", r.sim_ns),
            format!("{:.1}", r.elapsed_ns),
            speedup.map(|s| format!("{s:.4}")).unwrap_or_default(),
            format!("{:.4}", r.host_ns_per_elem),
            r.col_hits.to_string(),
            r.col_misses.to_string(),
            r.pool_leases.to_string(),
            r.matches.to_string(),
            r.sum.to_string(),
            r.pool_high_water.to_string(),
            r.fallback_causes.misaligned.to_string(),
            r.fallback_causes.cross_subarray.to_string(),
            r.fallback_causes.reserved.to_string(),
            r.fallback_causes.fragmented.to_string(),
        ]);
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("analytics_sharded.csv"))?;
    }
    Ok(format!(
        "## Analytics (sharded) — MIMDRAM-style bank-parallel SIMD\n\n{}",
        table.render()
    ))
}

/// Render the query-engine sweep: one row per allocator x shape x
/// placement (flat or sharded) cell. `param` is the shape's knob —
/// build-key count for `semi_join`, group count for `group_by`, `k`
/// for `top_k`. Writes `queries.csv` when `out_dir` is given.
pub fn queries(
    results: &[QueryResult],
    out_dir: Option<&Path>,
) -> Result<String> {
    let mut table = Table::new(vec![
        "allocator",
        "shape",
        "shards",
        "param",
        "batches",
        "waves",
        "rounds",
        "pud%",
        "fb causes",
        "elapsed",
        "host ns/elem",
        "col h/m",
        "matches",
        "agg",
    ])
    .left(0);
    let mut csv = Csv::new(vec![
        "allocator",
        "shape",
        "width",
        "rows",
        "shards",
        "param",
        "matches",
        "agg",
        "batches",
        "waves",
        "rounds",
        "compiles",
        "pud_row_fraction",
        "sim_ns",
        "elapsed_sim_ns",
        "host_ns_per_elem",
        "col_hits",
        "col_misses",
        "pool_leases",
        "pool_high_water",
        "fb_misaligned",
        "fb_cross_subarray",
        "fb_reserved",
        "fb_fragmented",
    ]);
    for r in results {
        table.row(vec![
            r.allocator.to_string(),
            r.shape.to_string(),
            if r.shards == 0 {
                "-".to_string()
            } else {
                r.shards.to_string()
            },
            r.param.to_string(),
            r.batches.to_string(),
            r.waves.to_string(),
            r.rounds.to_string(),
            format!("{:.0}%", r.pud_row_fraction() * 100.0),
            fmt_causes(&r.fallback_causes),
            fmt_ns(r.elapsed_ns),
            format!("{:.2}", r.host_ns_per_elem),
            format!("{}/{}", r.col_hits, r.col_misses),
            r.matches.to_string(),
            r.agg.to_string(),
        ]);
        csv.row(vec![
            r.allocator.to_string(),
            r.shape.to_string(),
            r.width.to_string(),
            r.rows.to_string(),
            r.shards.to_string(),
            r.param.to_string(),
            r.matches.to_string(),
            r.agg.to_string(),
            r.batches.to_string(),
            r.waves.to_string(),
            r.rounds.to_string(),
            r.compiles.to_string(),
            format!("{:.6}", r.pud_row_fraction()),
            format!("{:.1}", r.sim_ns),
            format!("{:.1}", r.elapsed_ns),
            format!("{:.4}", r.host_ns_per_elem),
            r.col_hits.to_string(),
            r.col_misses.to_string(),
            r.pool_leases.to_string(),
            r.pool_high_water.to_string(),
            r.fallback_causes.misaligned.to_string(),
            r.fallback_causes.cross_subarray.to_string(),
            r.fallback_causes.reserved.to_string(),
            r.fallback_causes.fragmented.to_string(),
        ]);
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("queries.csv"))?;
    }
    Ok(format!(
        "## Queries — semi-join / group-by / top-k over the PUD engine\n\n{}",
        table.render()
    ))
}

/// Render the multi-tenant serving study: one block per allocator —
/// per-tenant completion times under the DRR and back-to-back
/// schedules, then the percentile summary with the fairness win.
/// Writes `serve.csv` when `out_dir` is given.
pub fn serve(results: &[ServeResult], out_dir: Option<&Path>) -> Result<String> {
    let mut table = Table::new(vec![
        "allocator",
        "tenant",
        "traffic",
        "w",
        "ops",
        "drr-done",
        "b2b-done",
    ])
    .left(0)
    .left(1)
    .left(2);
    let mut csv = Csv::new(vec![
        "allocator",
        "tenant",
        "traffic",
        "weight",
        "ops",
        "drr_done_ns",
        "b2b_done_ns",
        "drr_p99_ns",
        "b2b_p99_ns",
        "identical",
        "pud_row_fraction",
    ]);
    let mut summary = String::new();
    for r in results {
        for t in &r.tenants {
            table.row(vec![
                r.allocator.to_string(),
                t.name.clone(),
                t.traffic.to_string(),
                t.weight.to_string(),
                t.ops.to_string(),
                fmt_ns(t.drr_done_ns),
                fmt_ns(t.b2b_done_ns),
            ]);
            csv.row(vec![
                r.allocator.to_string(),
                t.name.clone(),
                t.traffic.to_string(),
                t.weight.to_string(),
                t.ops.to_string(),
                format!("{:.1}", t.drr_done_ns),
                format!("{:.1}", t.b2b_done_ns),
                format!("{:.1}", r.drr_p99_ns),
                format!("{:.1}", r.b2b_p99_ns),
                r.identical.to_string(),
                format!("{:.6}", r.pud_row_fraction()),
            ]);
        }
        summary.push_str(&format!(
            "{:>14}: DRR p50/p99 {}/{} vs back-to-back {}/{} — \
             p99 {:.2}x better over {} round(s), results {}, \
             PUD-row fraction {:.0}%\n",
            r.allocator,
            fmt_ns(r.drr_p50_ns),
            fmt_ns(r.drr_p99_ns),
            fmt_ns(r.b2b_p50_ns),
            fmt_ns(r.b2b_p99_ns),
            r.p99_speedup(),
            r.drr_rounds,
            if r.identical { "identical" } else { "DIVERGED" },
            r.pud_row_fraction() * 100.0,
        ));
        summary.push_str(&format!(
            "{:>14}  admission: {} accepted, {} backpressured, {} rejected\n",
            "",
            r.admission.accepted,
            r.admission.queued,
            r.admission.rejected,
        ));
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("serve.csv"))?;
    }
    Ok(format!(
        "## Serve — multi-tenant fairness (DRR vs back-to-back)\n\n{}\n{}",
        table.render(),
        summary
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordStats;
    use crate::workloads::microbench::MicrobenchResult;

    fn cell(size: u64, sim: f64, base: f64, pud: u64, fb: u64) -> SweepCell {
        SweepCell {
            result: MicrobenchResult {
                micro: Micro::Copy,
                allocator: "puma",
                size,
                reps: 1,
                coord: CoordStats {
                    pud_rows: pud,
                    fallback_rows: fb,
                    ..Default::default()
                },
                alloc: Default::default(),
                sim_ns: sim,
            },
            baseline_ns: base,
        }
    }

    #[test]
    fn figure2_renders_table_and_chart() {
        let series = vec![(
            Micro::Copy,
            vec![cell(250, 100.0, 150.0, 0, 1), cell(8192, 50.0, 500.0, 1, 0)],
        )];
        let s = figure2(&series, None).unwrap();
        assert!(s.contains("Figure 2"));
        assert!(s.contains("copy-speedup"));
        assert!(s.contains("1.50x"));
        assert!(s.contains("10.0x"));
        assert!(s.contains("legend"));
    }

    #[test]
    fn motivation_renders_grid() {
        let rows = vec![
            (AllocatorKind::Malloc, 250u64, 0.0),
            (AllocatorKind::Malloc, 8192, 0.0),
            (AllocatorKind::HugePages, 250, 0.1),
            (AllocatorKind::HugePages, 8192, 0.6),
        ];
        let s = motivation(&rows, None).unwrap();
        assert!(s.contains("malloc"));
        assert!(s.contains("hugepages"));
        assert!(s.contains("60%"));
    }

    fn churn_result(pud: f64, pages: u64) -> ChurnResult {
        ChurnResult {
            samples: vec![crate::workloads::churn::EpochSample {
                epoch: 0,
                live_groups: 5,
                op_pud_fraction: pud,
                peak_occupancy: 0.95,
                pool_occupancy: 0.5,
                fragmentation: 0.25,
                free_regions: 100,
                regions_migrated_total: 3,
                pages_reclaimed_total: pages,
                op_ns: 1000.0,
                compact_ns: 50.0,
            }],
            alloc: Default::default(),
            coord: Default::default(),
            tenant_latency: vec![crate::workloads::churn::TenantLatency {
                tenant: 0,
                allocs: 6,
                alloc_p50_ns: 120,
                alloc_p99_ns: 480,
                ops: 10,
                op_p50_ns: 2_000,
                op_p99_ns: 9_000,
            }],
            steady_state_pud_fraction: pud,
            pages_returned: pages,
            final_occupancy: 0.1,
            final_pool_available: 4,
        }
    }

    #[test]
    fn churn_report_renders_comparison() {
        let off = churn_result(0.8, 0);
        let on = churn_result(0.95, 2);
        let s = churn(&off, Some(&on), None).unwrap();
        assert!(s.contains("Churn"));
        assert!(s.contains("80.0%"));
        assert!(s.contains("95.0%"));
        assert!(s.contains("compaction wins"));
        assert!(s.contains("puma (compact)"));
        assert!(s.contains("per-tenant latency"));
        assert!(s.contains("alloc-p99"));
        assert!(s.contains("t0"));
        // off-only rendering works too
        let solo = churn(&off, None, None).unwrap();
        assert!(!solo.contains("compaction wins"));
    }

    #[test]
    fn lifecycle_table_lists_new_counters() {
        let s = alloc_lifecycle(&[(
            "malloc",
            AllocStats {
                allocs: 2,
                pages_mapped: 7,
                pages_unmapped: 7,
                ..Default::default()
            },
        )]);
        assert!(s.contains("pages-unmap"));
        assert!(s.contains("malloc"));
        assert!(s.contains("reclaimed"));
    }

    #[test]
    fn op_cost_table_prices_xor_as_composite() {
        let s = op_costs(&TimingParams::default(), &EnergyParams::default());
        assert!(s.contains("xor"));
        // the xor row carries the 7-AAP / 3-TRA composite charges
        let xor_line = s.lines().find(|l| l.contains("xor")).unwrap();
        assert!(xor_line.contains('7'), "{xor_line}");
        assert!(xor_line.contains('3'), "{xor_line}");
        let and_line = s.lines().find(|l| l.contains("and")).unwrap();
        assert!(and_line.contains('4'), "{and_line}");
    }

    fn filter_result(alloc: &'static str, pud: f64, hand: f64) -> FilterResult {
        FilterResult {
            allocator: alloc,
            clauses: 3,
            columns: 8,
            rows: 1024,
            compile: crate::pud::compiler::CompileStats {
                leaves: 8,
                ops: 9,
                not_ops: 1,
                scratch_slots: 3,
                cse_hits: 1,
                ..Default::default()
            },
            waves: 4,
            compiled_ns: 900.0,
            elapsed_ns: 500.0,
            compiled_pud_fraction: pud,
            hand_ns: 5000.0,
            hand_pud_fraction: hand,
            matches: 42,
        }
    }

    #[test]
    fn filter_report_renders_comparison() {
        let rs = vec![
            filter_result("puma", 1.0, 0.2),
            filter_result("malloc", 0.0, 0.0),
        ];
        let s = filter(&rs, None).unwrap();
        assert!(s.contains("Filter"));
        assert!(s.contains("puma"));
        assert!(s.contains("100%"));
        assert!(s.contains("hand-pud%"));
        assert!(s.contains("10.0x"), "{s}");
    }

    fn sharded_result(shards: usize, elapsed_ns: f64) -> ShardedResult {
        ShardedResult {
            allocator: "puma",
            width: 8,
            shards,
            shard_count: shards,
            elems: 1 << 20,
            threshold: 128,
            matches: 1000,
            sum: 60_000,
            compile: Default::default(),
            waves: 9,
            sim_ns: 2.0 * elapsed_ns,
            elapsed_ns,
            pud_rows: 100,
            fallback_rows: 0,
            fallback_causes: Default::default(),
            pool_high_water: 8,
            pool_leases: 0,
            col_hits: 2,
            col_misses: 1,
            host_ns_per_elem: 1.25,
        }
    }

    #[test]
    fn sharded_report_computes_speedup_vs_s1() {
        let rs = vec![sharded_result(1, 40_000.0), sharded_result(8, 10_000.0)];
        let s = analytics_sharded(&rs, None).unwrap();
        assert!(s.contains("sharded"));
        assert!(s.contains("4.00x"), "{s}");
        assert!(s.contains("1.00x"), "{s}");
        let dir = std::env::temp_dir().join("puma_report_sharded_test");
        analytics_sharded(&rs, Some(&dir)).unwrap();
        assert!(dir.join("analytics_sharded.csv").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    fn query_result(
        alloc: &'static str,
        shape: &'static str,
        shards: usize,
    ) -> QueryResult {
        QueryResult {
            allocator: alloc,
            shape,
            width: 8,
            rows: 1 << 14,
            shards,
            param: 16,
            matches: 4100,
            agg: 523_000,
            batches: 3,
            waves: 12,
            sim_ns: 80_000.0,
            elapsed_ns: 40_000.0,
            pud_rows: 990,
            fallback_rows: 10,
            fallback_causes: CauseCounts {
                misaligned: 10,
                ..Default::default()
            },
            compiles: 0,
            rounds: if shape == "top_k" { 8 } else { 0 },
            col_hits: 3,
            col_misses: 1,
            pool_leases: 20,
            pool_high_water: 20,
            host_ns_per_elem: 2.5,
        }
    }

    #[test]
    fn queries_report_renders_and_writes_csv() {
        let rs = vec![
            query_result("puma", "semi_join", 0),
            query_result("puma", "top_k", 4),
            query_result("malloc", "group_by", 0),
        ];
        let s = queries(&rs, None).unwrap();
        assert!(s.contains("Queries"));
        assert!(s.contains("semi_join"));
        assert!(s.contains("top_k"));
        assert!(s.contains("99%"), "{s}");
        // flat cells render a dash in the shards column
        assert!(s.lines().any(|l| l.contains("semi_join") && l.contains(" - ")));
        let dir = std::env::temp_dir().join("puma_report_queries_test");
        queries(&rs, Some(&dir)).unwrap();
        let csv =
            std::fs::read_to_string(dir.join("queries.csv")).unwrap();
        assert!(csv.starts_with("allocator,shape,width,rows,shards,param,"));
        assert!(csv.contains("0.990000"));
        let _ = std::fs::remove_dir_all(dir);
    }

    fn serve_result() -> ServeResult {
        ServeResult {
            allocator: "puma",
            tenants: vec![
                crate::workloads::serve::TenantSummary {
                    name: "t0-filter".to_string(),
                    traffic: "filter",
                    weight: 1,
                    ops: 8,
                    drr_done_ns: 40_000.0,
                    b2b_done_ns: 90_000.0,
                },
                crate::workloads::serve::TenantSummary {
                    name: "t1-analytics".to_string(),
                    traffic: "analytics",
                    weight: 2,
                    ops: 8,
                    drr_done_ns: 52_000.0,
                    b2b_done_ns: 160_000.0,
                },
            ],
            ops_per_tenant: 8,
            drr_rounds: 5,
            drr_makespan_ns: 60_000.0,
            b2b_makespan_ns: 160_000.0,
            drr_p50_ns: 40_000.0,
            drr_p99_ns: 52_000.0,
            b2b_p50_ns: 90_000.0,
            b2b_p99_ns: 160_000.0,
            identical: true,
            admission: crate::serve::AdmissionStats {
                accepted: 10,
                queued: 6,
                rejected: 0,
            },
            pud_rows: 990,
            fallback_rows: 10,
        }
    }

    #[test]
    fn serve_report_renders_fairness_summary() {
        let rs = vec![serve_result()];
        let s = serve(&rs, None).unwrap();
        assert!(s.contains("Serve"));
        assert!(s.contains("t1-analytics"));
        assert!(s.contains("3.08x"), "{s}");
        assert!(s.contains("results identical"));
        assert!(s.contains("6 backpressured"));
        assert!(s.contains("99%"), "{s}");
        let dir = std::env::temp_dir().join("puma_report_serve_test");
        serve(&rs, Some(&dir)).unwrap();
        let csv = std::fs::read_to_string(dir.join("serve.csv")).unwrap();
        assert!(csv.starts_with("allocator,tenant,traffic,weight,ops,"));
        assert!(csv.contains("0.990000"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn writes_csvs() {
        let dir = std::env::temp_dir().join("puma_report_test");
        let series = vec![(Micro::Zero, vec![cell(250, 1.0, 2.0, 1, 0)])];
        figure2(&series, Some(&dir)).unwrap();
        motivation(&[(AllocatorKind::Malloc, 250, 0.0)], Some(&dir)).unwrap();
        churn(&churn_result(0.5, 1), None, Some(&dir)).unwrap();
        filter(&[filter_result("puma", 1.0, 0.5)], Some(&dir)).unwrap();
        assert!(dir.join("figure2.csv").exists());
        assert!(dir.join("motivation.csv").exists());
        assert!(dir.join("churn.csv").exists());
        assert!(dir.join("filter.csv").exists());
        let _ = std::fs::remove_dir_all(dir);
    }
}
