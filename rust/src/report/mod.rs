//! Report rendering: regenerates the paper's figures/tables as
//! markdown tables, ASCII charts, and CSV files.

use std::path::Path;

use anyhow::Result;

use crate::util::csvio::Csv;
use crate::util::table::{fnum, Table};
use crate::util::units::fmt_bytes;
use crate::workloads::microbench::{AllocatorKind, Micro};
use crate::workloads::sweep::SweepCell;

/// Render the Figure 2 reproduction: PUMA speedup over malloc, one
/// series per micro-benchmark, across allocation sizes.
pub fn figure2(
    series: &[(Micro, Vec<SweepCell>)],
    out_dir: Option<&Path>,
) -> Result<String> {
    let sizes: Vec<u64> = series
        .first()
        .map(|(_, cells)| cells.iter().map(|c| c.result.size).collect())
        .unwrap_or_default();
    let mut table = Table::new(
        std::iter::once("size".to_string())
            .chain(series.iter().map(|(m, _)| format!("{}-speedup", m.name())))
            .chain(series.iter().map(|(m, _)| format!("{}-pud%", m.name())))
            .collect::<Vec<String>>(),
    )
    .left(0);
    let mut csv = Csv::new(vec![
        "size_bytes",
        "micro",
        "allocator",
        "sim_ns",
        "baseline_ns",
        "speedup",
        "pud_fraction",
    ]);
    for (i, &size) in sizes.iter().enumerate() {
        let mut row = vec![fmt_bytes(size)];
        for (_, cells) in series {
            row.push(format!("{}x", fnum(cells[i].speedup())));
        }
        for (_, cells) in series {
            row.push(format!("{:.0}%", cells[i].result.pud_fraction() * 100.0));
        }
        table.row(row);
        for (m, cells) in series {
            let c = &cells[i];
            csv.row(vec![
                size.to_string(),
                m.name().to_string(),
                c.result.allocator.to_string(),
                format!("{:.1}", c.result.sim_ns),
                format!("{:.1}", c.baseline_ns),
                format!("{:.4}", c.speedup()),
                format!("{:.4}", c.result.pud_fraction()),
            ]);
        }
    }
    let chart = crate::util::chart::line_chart(
        &sizes.iter().map(|s| fmt_bytes(*s)).collect::<Vec<_>>(),
        &series
            .iter()
            .map(|(m, cells)| {
                (
                    format!("{}-speedup", m.name()),
                    cells.iter().map(|c| c.speedup()).collect(),
                )
            })
            .collect::<Vec<_>>(),
        12,
    );
    if let Some(dir) = out_dir {
        csv.write(dir.join("figure2.csv"))?;
    }
    Ok(format!(
        "## Figure 2 — PUMA speedup vs malloc (simulated time)\n\n{}\n{}",
        table.render(),
        chart
    ))
}

/// Render the §1 motivation study: PUD-executable fraction per
/// allocator per size.
pub fn motivation(
    rows: &[(AllocatorKind, u64, f64)],
    out_dir: Option<&Path>,
) -> Result<String> {
    // collect the size axis
    let mut sizes: Vec<u64> = rows.iter().map(|(_, s, _)| *s).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let mut kinds: Vec<AllocatorKind> = Vec::new();
    for (k, _, _) in rows {
        if !kinds.contains(k) {
            kinds.push(*k);
        }
    }
    let mut table = Table::new(
        std::iter::once("allocator".to_string())
            .chain(sizes.iter().map(|s| fmt_bytes(*s)))
            .collect::<Vec<String>>(),
    )
    .left(0);
    let mut csv = Csv::new(vec!["allocator", "size_bytes", "pud_fraction"]);
    for k in &kinds {
        let mut row = vec![k.name().to_string()];
        for s in &sizes {
            let frac = rows
                .iter()
                .find(|(rk, rs, _)| rk == k && rs == s)
                .map(|(_, _, f)| *f)
                .unwrap_or(0.0);
            row.push(format!("{:.0}%", frac * 100.0));
        }
        table.row(row);
    }
    for (k, s, f) in rows {
        csv.row(vec![
            k.name().to_string(),
            s.to_string(),
            format!("{f:.4}"),
        ]);
    }
    if let Some(dir) = out_dir {
        csv.write(dir.join("motivation.csv"))?;
    }
    Ok(format!(
        "## §1 motivation — PUD-executable operations per allocator\n\n{}",
        table.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordStats;
    use crate::workloads::microbench::MicrobenchResult;

    fn cell(size: u64, sim: f64, base: f64, pud: u64, fb: u64) -> SweepCell {
        SweepCell {
            result: MicrobenchResult {
                micro: Micro::Copy,
                allocator: "puma",
                size,
                reps: 1,
                coord: CoordStats {
                    pud_rows: pud,
                    fallback_rows: fb,
                    ..Default::default()
                },
                alloc: Default::default(),
                sim_ns: sim,
            },
            baseline_ns: base,
        }
    }

    #[test]
    fn figure2_renders_table_and_chart() {
        let series = vec![(
            Micro::Copy,
            vec![cell(250, 100.0, 150.0, 0, 1), cell(8192, 50.0, 500.0, 1, 0)],
        )];
        let s = figure2(&series, None).unwrap();
        assert!(s.contains("Figure 2"));
        assert!(s.contains("copy-speedup"));
        assert!(s.contains("1.50x"));
        assert!(s.contains("10.0x"));
        assert!(s.contains("legend"));
    }

    #[test]
    fn motivation_renders_grid() {
        let rows = vec![
            (AllocatorKind::Malloc, 250u64, 0.0),
            (AllocatorKind::Malloc, 8192, 0.0),
            (AllocatorKind::HugePages, 250, 0.1),
            (AllocatorKind::HugePages, 8192, 0.6),
        ];
        let s = motivation(&rows, None).unwrap();
        assert!(s.contains("malloc"));
        assert!(s.contains("hugepages"));
        assert!(s.contains("60%"));
    }

    #[test]
    fn writes_csvs() {
        let dir = std::env::temp_dir().join("puma_report_test");
        let series = vec![(Micro::Zero, vec![cell(250, 1.0, 2.0, 1, 0)])];
        figure2(&series, Some(&dir)).unwrap();
        motivation(&[(AllocatorKind::Malloc, 250, 0.0)], Some(&dir)).unwrap();
        assert!(dir.join("figure2.csv").exists());
        assert!(dir.join("motivation.csv").exists());
        let _ = std::fs::remove_dir_all(dir);
    }
}
