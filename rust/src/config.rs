//! Run configuration: defaults, key=value config files, and CLI
//! overrides.
//!
//! Config files are simple `key = value` lines (with `#` comments);
//! the same keys are accepted as `--key value` CLI flags. This is the
//! framework-style config system the launcher (`puma` binary) uses.
//!
//! Keys:
//! ```text
//! devicetree    = path to a DRAM device-tree description (default: builtin 8 GiB)
//! scheme        = row_major | bank_xor | subarray_low (ignored with devicetree)
//! huge_pages    = boot-time hugetlb pool size            (default 256)
//! puma_pages    = pages pim_preallocate moves to PUMA    (default 64)
//! churn_rounds  = buddy aging rounds before workloads    (default 20000)
//! reps          = bulk ops per micro-benchmark cell      (default 4)
//! seed          = PRNG seed                              (default 0xF16)
//! sizes         = comma-separated allocation sizes ("250,64KiB,6Mb")
//! artifacts     = artifacts dir for the XLA runtime ("none" disables)
//! out           = output directory for CSVs              (default "out")
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;

use crate::dram::address::InterleaveScheme;
use crate::dram::devicetree;
use crate::dram::geometry::DramGeometry;
use crate::util::units::parse_size;
use crate::workloads::sweep::{paper_sizes, SweepConfig};

/// Parsed run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub scheme: InterleaveScheme,
    pub huge_pages: usize,
    pub puma_pages: usize,
    pub churn_rounds: usize,
    pub reps: u32,
    pub seed: u64,
    pub sizes: Vec<u64>,
    pub artifacts: Option<PathBuf>,
    pub out: PathBuf,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            scheme: InterleaveScheme::row_major(DramGeometry::default()),
            huge_pages: 256,
            puma_pages: 64,
            churn_rounds: 20_000,
            reps: 16,
            seed: 0xF16,
            sizes: paper_sizes(),
            artifacts: default_artifacts(),
            out: PathBuf::from("out"),
        }
    }
}

/// The artifacts directory if it exists in the working directory.
pub fn default_artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    p.join("manifest.tsv").exists().then_some(p)
}

impl Config {
    /// Apply `key = value` pairs.
    pub fn apply(&mut self, pairs: &FxHashMap<String, String>) -> Result<()> {
        for (k, v) in pairs {
            match k.as_str() {
                "devicetree" => {
                    let text = std::fs::read_to_string(v)
                        .with_context(|| format!("reading devicetree {v}"))?;
                    self.scheme = devicetree::parse(&text)?;
                }
                "scheme" => {
                    let g = self.scheme.geometry.clone();
                    self.scheme = match v.as_str() {
                        "row_major" => InterleaveScheme::row_major(g),
                        "bank_xor" => InterleaveScheme::bank_xor(g),
                        "subarray_low" => InterleaveScheme::subarray_low(g),
                        other => bail!("unknown scheme {other:?}"),
                    };
                }
                "huge_pages" => self.huge_pages = v.parse().context("huge_pages")?,
                "puma_pages" => self.puma_pages = v.parse().context("puma_pages")?,
                "churn_rounds" => {
                    self.churn_rounds = v.parse().context("churn_rounds")?
                }
                "reps" => self.reps = v.parse().context("reps")?,
                "seed" => {
                    self.seed = if let Some(hex) = v.strip_prefix("0x") {
                        u64::from_str_radix(hex, 16).context("seed")?
                    } else {
                        v.parse().context("seed")?
                    }
                }
                "sizes" => {
                    self.sizes = v
                        .split(',')
                        .map(|s| parse_size(s.trim()))
                        .collect::<Result<Vec<u64>>>()?;
                    if self.sizes.is_empty() {
                        bail!("empty sizes list");
                    }
                }
                "artifacts" => {
                    self.artifacts = match v.as_str() {
                        "none" | "" => None,
                        p => Some(PathBuf::from(p)),
                    }
                }
                "out" => self.out = PathBuf::from(v),
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// Load a config file of `key = value` lines.
    pub fn load_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let mut pairs = FxHashMap::default();
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path}:{}: expected key = value", i + 1))?;
            pairs.insert(k.trim().to_string(), v.trim().to_string());
        }
        let mut cfg = Config::default();
        cfg.apply(&pairs)?;
        Ok(cfg)
    }

    /// Convert to a sweep configuration.
    pub fn sweep(&self) -> SweepConfig {
        SweepConfig {
            scheme: self.scheme.clone(),
            sizes: self.sizes.clone(),
            reps: self.reps,
            huge_pages: self.huge_pages,
            puma_pages: self.puma_pages,
            churn_rounds: self.churn_rounds,
            seed: self.seed,
            artifacts: self.artifacts.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pairs(kv: &[(&str, &str)]) -> FxHashMap<String, String> {
        kv.iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.scheme.geometry.capacity_bytes(), 8 << 30);
        assert_eq!(c.sizes, paper_sizes());
    }

    #[test]
    fn apply_overrides() {
        let mut c = Config::default();
        c.apply(&pairs(&[
            ("huge_pages", "32"),
            ("seed", "0xABC"),
            ("sizes", "250, 4KiB, 6Mb"),
            ("scheme", "bank_xor"),
            ("artifacts", "none"),
        ]))
        .unwrap();
        assert_eq!(c.huge_pages, 32);
        assert_eq!(c.seed, 0xABC);
        assert_eq!(c.sizes, vec![250, 4096, 6 * (1 << 20) / 8]);
        assert!(c.scheme.xor_bank_with_row_low);
        assert!(c.artifacts.is_none());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut c = Config::default();
        assert!(c.apply(&pairs(&[("nope", "1")])).is_err());
        assert!(c.apply(&pairs(&[("reps", "many")])).is_err());
        assert!(c.apply(&pairs(&[("scheme", "diagonal")])).is_err());
        assert!(c.apply(&pairs(&[("sizes", "")])).is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("puma_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.conf");
        std::fs::write(
            &path,
            "# test config\nhuge_pages = 16\nreps = 2  # inline comment\n",
        )
        .unwrap();
        let c = Config::load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.huge_pages, 16);
        assert_eq!(c.reps, 2);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn devicetree_key_loads_scheme() {
        let dir = std::env::temp_dir().join("puma_cfg_dt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dram.dts");
        let scheme = InterleaveScheme::bank_xor(DramGeometry::default());
        std::fs::write(&path, crate::dram::devicetree::render(&scheme)).unwrap();
        let mut c = Config::default();
        c.apply(&pairs(&[("devicetree", path.to_str().unwrap())]))
            .unwrap();
        assert_eq!(c.scheme, scheme);
        let _ = std::fs::remove_dir_all(dir);
    }
}
