//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! ```text
//! puma <command> [--config FILE] [--key value ...]
//!
//! commands:
//!   fig2         reproduce Figure 2 (three micro-benchmarks x sizes)
//!   motivation   reproduce the §1 allocator-eligibility study
//!   micro        run one micro-benchmark cell
//!                  (--micro zero|copy|aand --alloc NAME --size SIZE)
//!   info         print the machine description (geometry, scheme,
//!                  timing, artifact inventory)
//!   help         this text
//! ```

use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;

use crate::alloc::puma::FitPolicy;
use crate::analysis::lint::{self as lint_diag, Diagnostic, Severity};
use crate::analysis::VerifyLevel;
use crate::config::Config;
use crate::coordinator::system::{System, SystemConfig};
use crate::report;
use crate::util::table::Table;
use crate::util::units::{fmt_bytes, fmt_ns, parse_size};
use crate::workloads::microbench::{self, AllocatorKind, Micro};
use crate::workloads::sweep;

/// Parsed command line.
#[derive(Debug)]
pub struct Cli {
    pub command: String,
    pub flags: FxHashMap<String, String>,
}

/// Parse `args` (without argv[0]): one positional command plus
/// `--key value` pairs.
pub fn parse_args(args: &[String]) -> Result<Cli> {
    let mut command = None;
    let mut flags = FxHashMap::default();
    let mut it = args.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(), // bare flag
            };
            flags.insert(key.to_string(), value);
        } else if command.is_none() {
            command = Some(arg.clone());
        } else {
            bail!("unexpected positional argument {arg:?}");
        }
    }
    Ok(Cli {
        command: command.unwrap_or_else(|| "help".to_string()),
        flags,
    })
}

/// Build the config from `--config FILE` plus per-flag overrides.
pub fn build_config(cli: &Cli) -> Result<Config> {
    let mut cfg = match cli.flags.get("config") {
        Some(path) => Config::load_file(path)?,
        None => Config::default(),
    };
    let mut overrides = cli.flags.clone();
    overrides.remove("config");
    // command-specific flags are not config keys
    for k in [
        "micro", "alloc", "size", "batch", "tenants", "epochs", "mode",
        "clauses", "widths", "elems", "threshold", "shards", "rows", "width",
        "groups", "build_keys", "k", "export", "ops", "quantum", "json",
    ] {
        overrides.remove(k);
    }
    cfg.apply(&overrides)?;
    Ok(cfg)
}

fn parse_alloc(name: &str) -> Result<AllocatorKind> {
    Ok(match name {
        "malloc" => AllocatorKind::Malloc,
        "posix_memalign" | "memalign" => AllocatorKind::Memalign,
        "hugepages" | "huge" => AllocatorKind::HugePages,
        "puma" => AllocatorKind::Puma(FitPolicy::WorstFit),
        "puma-bestfit" => AllocatorKind::Puma(FitPolicy::BestFit),
        "puma-firstfit" => AllocatorKind::Puma(FitPolicy::FirstFit),
        other => bail!("unknown allocator {other:?}"),
    })
}

fn parse_micro(name: &str) -> Result<Micro> {
    Ok(match name {
        "zero" => Micro::Zero,
        "copy" => Micro::Copy,
        "aand" | "and" => Micro::Aand,
        other => bail!("unknown micro-benchmark {other:?}"),
    })
}

/// Run the CLI; returns the process exit code.
pub fn run(args: &[String]) -> Result<i32> {
    let cli = parse_args(args)?;
    match cli.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(0)
        }
        "info" => {
            let cfg = build_config(&cli)?;
            cmd_info(&cfg)
        }
        "fig2" => {
            let cfg = build_config(&cli)?;
            cmd_fig2(&cfg)
        }
        "motivation" => {
            let cfg = build_config(&cli)?;
            cmd_motivation(&cfg)
        }
        "churn" => {
            let cfg = build_config(&cli)?;
            let tenants: usize = cli
                .flags
                .get("tenants")
                .map(String::as_str)
                .unwrap_or("3")
                .parse()
                .context("tenants")?;
            let epochs: usize = cli
                .flags
                .get("epochs")
                .map(String::as_str)
                .unwrap_or("10")
                .parse()
                .context("epochs")?;
            let mode = cli
                .flags
                .get("mode")
                .map(String::as_str)
                .unwrap_or("both");
            cmd_churn(&cfg, tenants, epochs, mode)
        }
        "filter" => {
            let cfg = build_config(&cli)?;
            let clauses: usize = cli
                .flags
                .get("clauses")
                .map(String::as_str)
                .unwrap_or("3")
                .parse()
                .context("clauses")?;
            let alloc = cli
                .flags
                .get("alloc")
                .map(|a| parse_alloc(a))
                .transpose()?;
            cmd_filter(&cfg, clauses, alloc)
        }
        "analytics" => {
            let cfg = build_config(&cli)?;
            let widths: Vec<u32> = cli
                .flags
                .get("widths")
                .map(String::as_str)
                .unwrap_or("4,8,16")
                .split(',')
                .map(|s| s.trim().parse::<u32>().context("widths"))
                .collect::<Result<_>>()?;
            let elems: usize = cli
                .flags
                .get("elems")
                .map(String::as_str)
                .unwrap_or("65536")
                .parse()
                .context("elems")?;
            let threshold: f64 = cli
                .flags
                .get("threshold")
                .map(String::as_str)
                .unwrap_or("0.5")
                .parse()
                .context("threshold")?;
            let alloc = cli
                .flags
                .get("alloc")
                .map(|a| parse_alloc(a))
                .transpose()?;
            let shards: Option<Vec<usize>> = cli
                .flags
                .get("shards")
                .map(|s| {
                    s.split(',')
                        .map(|x| x.trim().parse::<usize>().context("shards"))
                        .collect::<Result<_>>()
                })
                .transpose()?;
            cmd_analytics(&cfg, widths, elems, threshold, alloc, shards)
        }
        "query" => {
            let cfg = build_config(&cli)?;
            let get = |key: &str, dflt: &str| -> String {
                cli.flags
                    .get(key)
                    .cloned()
                    .unwrap_or_else(|| dflt.to_string())
            };
            let rows: usize = get("rows", "65536").parse().context("rows")?;
            let width: u32 = get("width", "8").parse().context("width")?;
            let groups: u64 = get("groups", "8").parse().context("groups")?;
            let build_keys: usize =
                get("build_keys", "16").parse().context("build_keys")?;
            let k: u64 = get("k", "4096").parse().context("k")?;
            let threshold: f64 =
                get("threshold", "0.5").parse().context("threshold")?;
            let shards: usize = get("shards", "4").parse().context("shards")?;
            let alloc = cli
                .flags
                .get("alloc")
                .map(|a| parse_alloc(a))
                .transpose()?;
            cmd_query(
                &cfg, rows, width, groups, build_keys, k, threshold, shards,
                alloc,
            )
        }
        "serve" => {
            let cfg = build_config(&cli)?;
            let get = |key: &str, dflt: &str| -> String {
                cli.flags
                    .get(key)
                    .cloned()
                    .unwrap_or_else(|| dflt.to_string())
            };
            let tenants: usize = get("tenants", "8").parse().context("tenants")?;
            let ops: usize = get("ops", "12").parse().context("ops")?;
            let quantum: u64 = get("quantum", "8").parse().context("quantum")?;
            let alloc = cli
                .flags
                .get("alloc")
                .map(|a| parse_alloc(a))
                .transpose()?;
            cmd_serve(&cfg, tenants, ops, quantum, alloc)
        }
        "lint" => {
            let cfg = build_config(&cli)?;
            let alloc = cli
                .flags
                .get("alloc")
                .map(|a| parse_alloc(a))
                .transpose()?;
            let json = cli.flags.get("json").cloned();
            cmd_lint(&cfg, alloc, json.as_deref())
        }
        "trace" => {
            let cfg = build_config(&cli)?;
            let export = cli.flags.get("export").map(String::as_str);
            cmd_trace(&cfg, export)
        }
        "stats" => {
            let cfg = build_config(&cli)?;
            cmd_stats(&cfg)
        }
        "micro" => {
            let cfg = build_config(&cli)?;
            let micro = parse_micro(
                cli.flags
                    .get("micro")
                    .map(String::as_str)
                    .unwrap_or("aand"),
            )?;
            let alloc = parse_alloc(
                cli.flags
                    .get("alloc")
                    .map(String::as_str)
                    .unwrap_or("puma"),
            )?;
            let size = parse_size(
                cli.flags.get("size").map(String::as_str).unwrap_or("64KiB"),
            )?;
            let batched = cli.flags.get("batch").map(String::as_str) == Some("true");
            cmd_micro(&cfg, micro, alloc, size, batched)
        }
        other => bail!("unknown command {other:?} (try `puma help`)"),
    }
}

const HELP: &str = "\
puma — PUMA (PUD memory allocation) full-system reproduction

usage: puma <command> [--config FILE] [--key value ...]

commands:
  fig2         reproduce Figure 2 (zero/copy/aand x allocation sizes)
  motivation   reproduce the §1 allocator-eligibility study
  micro        one cell: --micro zero|copy|aand --alloc NAME --size SIZE
               (--batch submits all reps as one pipeline batch)
  churn        multi-tenant aging + reclamation/compaction lifecycle:
               --tenants N --epochs N --mode off|on|both
  filter       compiled predicate-filter workload, swept over clause
               counts and allocators: --clauses N [--alloc NAME]
  analytics    filter-then-sum over a vertical (bit-transposed) column
               table, swept over bit-widths and allocators:
               --widths 4,8,16 --elems N --threshold FRAC [--alloc NAME]
               [--shards 1,2,4,8: MIMDRAM-style bank-sharded SIMD scale
               sweep, each cell verified against the unsharded path]
  query        analytics query engine (bitmap semi-join, single-batch
               group-by, top-k threshold bisection) over a TPC-H-flavored
               micro-table, every cell verified against a scalar oracle:
               --rows N --width W --groups N --build_keys N --k N
               --threshold FRAC --shards N [--alloc NAME]
  serve        multi-tenant serving study: twin gateways drain identical
               mixed traffic under the DRR fairness scheduler vs
               back-to-back, verifying byte-identical results and
               comparing tenant-completion percentiles:
               --tenants N --ops N --quantum ROWS [--alloc NAME]
  lint         replay the filter/analytics/queries workloads with the
               static verifier at full strength (every compiled stream
               re-checked: dataflow, hazard waves, translation
               validation) and the placement linter attributing every
               fallback row to the PUMA requirement it violated; prints
               the diagnostics table, writes them as JSON, and exits
               nonzero only on verifier errors:
               [--alloc NAME] [--json FILE]
  trace        run a small mixed-op batch with the wave tracer enabled
               and print a pipeline summary; --export DIR also writes
               trace.json (open in ui.perfetto.dev — one lane per
               active bank), a replay-checked DDR command stream, and
               a Prometheus metrics dump (DESIGN.md §14)
  stats        run the same batch and print the metrics registry as
               Prometheus text (histograms as p50/p90/p99 summaries)
  info         print machine description and artifact inventory
  help         this text

config keys (also accepted as --flags): devicetree, scheme, huge_pages,
puma_pages, churn_rounds, reps, seed, sizes, artifacts, out";

fn cmd_info(cfg: &Config) -> Result<i32> {
    let g = &cfg.scheme.geometry;
    println!("machine:");
    println!("  capacity        {}", fmt_bytes(g.capacity_bytes()));
    println!(
        "  geometry        {} ch x {} rank x {} bank x {} subarrays x {} rows x {}",
        g.channels,
        g.ranks_per_channel,
        g.banks_per_rank,
        g.subarrays_per_bank,
        g.rows_per_subarray,
        fmt_bytes(g.row_bytes as u64)
    );
    println!("  subarrays       {}", g.total_subarrays());
    println!(
        "  hugetlb pool    {} pages ({})",
        cfg.huge_pages,
        fmt_bytes(cfg.huge_pages as u64 * crate::os::HUGE_PAGE_SIZE)
    );
    println!("\ndevice tree:\n{}", crate::dram::devicetree::render(&cfg.scheme));
    match &cfg.artifacts {
        Some(dir) => {
            let entries = crate::runtime::manifest::load(dir)?;
            println!("artifacts ({}): {} HLO modules", dir.display(), entries.len());
            let mut ops: Vec<&str> =
                entries.iter().map(|e| e.op.as_str()).collect();
            ops.sort();
            ops.dedup();
            println!("  ops: {}", ops.join(", "));
        }
        None => println!("artifacts: none (scalar fallback)"),
    }
    println!(
        "\nPUD op costs (per row):\n{}",
        report::op_costs(
            &crate::dram::timing::TimingParams::default(),
            &crate::dram::energy::EnergyParams::default(),
        )
    );
    Ok(0)
}

fn cmd_filter(
    cfg: &Config,
    clauses: usize,
    alloc: Option<AllocatorKind>,
) -> Result<i32> {
    let clauses = clauses.max(1);
    let fcfg = crate::workloads::filter::FilterConfig {
        clauses,
        huge_pages: cfg.huge_pages,
        puma_pages: cfg.puma_pages.max(2),
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        ..Default::default()
    };
    let kinds: Vec<AllocatorKind> = match alloc {
        Some(k) => vec![k],
        None => vec![
            AllocatorKind::Malloc,
            AllocatorKind::HugePages,
            AllocatorKind::Puma(FitPolicy::WorstFit),
        ],
    };
    let clause_counts: Vec<usize> = (1..=clauses).collect();
    eprintln!(
        "running filter sweep: {} clause count(s) x {} allocator(s) ...",
        clause_counts.len(),
        kinds.len()
    );
    let results =
        crate::workloads::filter::sweep(&cfg.scheme, &fcfg, &clause_counts, &kinds)?;
    println!("{}", report::filter(&results, Some(&cfg.out))?);
    let (expr, columns) = crate::workloads::filter::predicate(clauses);
    println!("predicate ({columns} columns): {expr}");
    println!("(raw series: {}/filter.csv)", cfg.out.display());
    Ok(0)
}

fn cmd_analytics(
    cfg: &Config,
    widths: Vec<u32>,
    elems: usize,
    threshold: f64,
    alloc: Option<AllocatorKind>,
    shards: Option<Vec<usize>>,
) -> Result<i32> {
    let kinds: Vec<AllocatorKind> = match alloc {
        Some(k) => vec![k],
        None => vec![
            AllocatorKind::Malloc,
            AllocatorKind::Memalign,
            AllocatorKind::HugePages,
            AllocatorKind::Puma(FitPolicy::WorstFit),
        ],
    };
    if let Some(shards) = shards {
        // sharded scale sweep: every sharded cell is verified against
        // the unsharded path inside the workload
        let scfg = crate::workloads::analytics::ShardedConfig {
            elems,
            widths,
            shards,
            threshold_frac: threshold,
            huge_pages: cfg.huge_pages,
            puma_pages: cfg.puma_pages.max(2),
            churn_rounds: cfg.churn_rounds,
            seed: cfg.seed,
        };
        eprintln!(
            "running sharded analytics sweep: {} width(s) x {} shard count(s) \
             x {} allocator(s), {} elems ...",
            scfg.widths.len(),
            scfg.shards.len(),
            kinds.len(),
            scfg.elems
        );
        let results =
            crate::workloads::analytics::sweep_sharded(&cfg.scheme, &scfg, &kinds)?;
        println!("{}", report::analytics_sharded(&results, Some(&cfg.out))?);
        println!(
            "(raw series: {}/analytics_sharded.csv)",
            cfg.out.display()
        );
        return Ok(0);
    }
    let acfg = crate::workloads::analytics::AnalyticsConfig {
        elems,
        widths,
        threshold_frac: threshold,
        huge_pages: cfg.huge_pages,
        puma_pages: cfg.puma_pages.max(2),
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
    };
    eprintln!(
        "running analytics sweep: {} width(s) x {} allocator(s), {} elems ...",
        acfg.widths.len(),
        kinds.len(),
        acfg.elems
    );
    let results =
        crate::workloads::analytics::sweep(&cfg.scheme, &acfg, &kinds)?;
    println!("{}", report::analytics(&results, Some(&cfg.out))?);
    println!("(raw series: {}/analytics.csv)", cfg.out.display());
    Ok(0)
}

#[allow(clippy::too_many_arguments)]
fn cmd_query(
    cfg: &Config,
    rows: usize,
    width: u32,
    groups: u64,
    build_keys: usize,
    k: u64,
    threshold: f64,
    shards: usize,
    alloc: Option<AllocatorKind>,
) -> Result<i32> {
    let kinds: Vec<AllocatorKind> = match alloc {
        Some(kind) => vec![kind],
        None => vec![
            AllocatorKind::Malloc,
            AllocatorKind::Memalign,
            AllocatorKind::HugePages,
            AllocatorKind::Puma(FitPolicy::WorstFit),
        ],
    };
    let qcfg = crate::workloads::queries::QueriesConfig {
        rows,
        width,
        groups,
        build_keys,
        k,
        threshold_frac: threshold,
        shards,
        huge_pages: cfg.huge_pages,
        puma_pages: cfg.puma_pages.max(2),
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
    };
    eprintln!(
        "running query sweep: 3 shape(s){} x {} allocator(s), {} rows ...",
        if shards > 1 { " x flat+sharded" } else { "" },
        kinds.len(),
        qcfg.rows
    );
    let results =
        crate::workloads::queries::sweep(&cfg.scheme, &qcfg, &kinds)?;
    println!("{}", report::queries(&results, Some(&cfg.out))?);
    println!("(raw series: {}/queries.csv)", cfg.out.display());
    Ok(0)
}

/// Boot a system with the verifier forced to `Full` (independent of
/// `PUMA_VERIFY`), so `puma lint` always checks what it replays.
fn boot_verified(cfg: &Config) -> Result<System> {
    System::boot(SystemConfig {
        scheme: cfg.scheme.clone(),
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds.min(2_000),
        seed: cfg.seed,
        artifacts: None,
        verify: VerifyLevel::Full,
        ..Default::default()
    })
}

/// Prefix every diagnostic's site with the workload that produced it.
fn scoped(workload: &str, ds: Vec<Diagnostic>) -> Vec<Diagnostic> {
    ds.into_iter()
        .map(|mut d| {
            d.site = format!("{workload}/{}", d.site);
            d
        })
        .collect()
}

fn cmd_lint(
    cfg: &Config,
    alloc: Option<AllocatorKind>,
    json: Option<&str>,
) -> Result<i32> {
    use crate::alloc::scratch::ScratchPool;
    use crate::pud::arith::ShardedScratch;
    use crate::workloads::{analytics, filter, queries};

    let kind = alloc.unwrap_or(AllocatorKind::Puma(FitPolicy::WorstFit));
    let pages = cfg.puma_pages.max(8);
    let mut diags: Vec<Diagnostic> = Vec::new();

    // --- filter: the compiled-predicate batch over hint-aligned columns
    eprintln!("linting filter ({}) ...", kind.name());
    {
        let mut sys = boot_verified(cfg)?;
        let pid = sys.spawn();
        let mut a = kind.build(&mut sys, pages)?;
        let (expr, columns) = filter::predicate(3);
        let len = crate::pud::arith::plane_bytes(16 * 1024);
        let first = sys.alloc(a.as_mut(), pid, len)?;
        let mut cols = vec![first];
        for _ in 1..columns {
            cols.push(sys.alloc_align(a.as_mut(), pid, len, first)?);
        }
        let dst = sys.alloc_align(a.as_mut(), pid, len, first)?;
        let mut pool = ScratchPool::new();
        sys.run_expr(a.as_mut(), pid, &expr, &cols, dst, len, &mut pool)?;
        diags.extend(scoped("filter", sys.take_diagnostics()));
    }

    // --- analytics: filter-then-sum cells across bit-widths
    eprintln!("linting analytics ({}) ...", kind.name());
    {
        let mut sys = boot_verified(cfg)?;
        let pid = sys.spawn();
        let mut a = kind.build(&mut sys, pages)?;
        let acfg = analytics::AnalyticsConfig {
            elems: 16 * 1024,
            widths: vec![4, 8],
            huge_pages: cfg.huge_pages,
            puma_pages: pages,
            churn_rounds: cfg.churn_rounds.min(500),
            seed: cfg.seed,
            ..Default::default()
        };
        let mut pools = ShardedScratch::new();
        for &w in &acfg.widths {
            analytics::run_cell(
                &mut sys,
                a.as_mut(),
                pid,
                kind.name(),
                &acfg,
                w,
                &mut pools,
            )?;
        }
        sys.trim_pools(a.as_mut(), pid, &mut pools, 0)?;
        sys.flush_columns(a.as_mut(), pid)?;
        for k in 0..pools.n_pools() {
            diags.extend(scoped(
                "analytics",
                lint_diag::lint_scratch_pool(pools.pool(k), &format!("pool{k}")),
            ));
        }
        diags.extend(scoped("analytics", sys.take_diagnostics()));
    }

    // --- queries: semi-join / group-by / top-k over the micro-table
    eprintln!("linting queries ({}) ...", kind.name());
    {
        let mut sys = boot_verified(cfg)?;
        let pid = sys.spawn();
        let mut a = kind.build(&mut sys, pages)?;
        let qcfg = queries::QueriesConfig {
            rows: 16 * 1024,
            k: 1024,
            shards: 0,
            huge_pages: cfg.huge_pages,
            puma_pages: pages,
            churn_rounds: cfg.churn_rounds.min(500),
            seed: cfg.seed,
            ..Default::default()
        };
        let mut pools = ShardedScratch::new();
        queries::run_cell_semi_join(
            &mut sys, a.as_mut(), pid, kind.name(), &qcfg, &mut pools,
        )?;
        queries::run_cell_group_by(
            &mut sys, a.as_mut(), pid, kind.name(), &qcfg, &mut pools,
        )?;
        queries::run_cell_top_k(
            &mut sys, a.as_mut(), pid, kind.name(), &qcfg, &mut pools,
        )?;
        sys.trim_pools(a.as_mut(), pid, &mut pools, 0)?;
        sys.flush_columns(a.as_mut(), pid)?;
        for k in 0..pools.n_pools() {
            diags.extend(scoped(
                "queries",
                lint_diag::lint_scratch_pool(pools.pool(k), &format!("pool{k}")),
            ));
        }
        diags.extend(scoped("queries", sys.take_diagnostics()));
    }

    if diags.is_empty() {
        println!(
            "lint: clean — every compiled stream verified and every row \
             placement-attributed ({} placement)",
            kind.name()
        );
    } else {
        let mut table =
            Table::new(vec!["severity", "lint", "site", "message"]).left(0);
        for d in &diags {
            table.row(vec![
                d.severity.to_string(),
                d.lint.to_string(),
                d.site.clone(),
                d.message.clone(),
            ]);
        }
        println!("{}", table.render());
    }
    let errors =
        diags.iter().filter(|d| d.severity >= Severity::Error).count();
    let warnings =
        diags.iter().filter(|d| d.severity == Severity::Warning).count();
    let notes = diags.iter().filter(|d| d.severity == Severity::Note).count();
    println!(
        "{} diagnostic(s): {errors} error(s), {warnings} warning(s), \
         {notes} note(s)",
        diags.len()
    );
    let json_path = match json {
        Some(p) => std::path::PathBuf::from(p),
        None => cfg.out.join("lint.json"),
    };
    if let Some(parent) = json_path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&json_path, lint_diag::diagnostics_to_json(&diags))?;
    println!("(diagnostics json: {})", json_path.display());
    Ok(if errors > 0 { 1 } else { 0 })
}

fn cmd_fig2(cfg: &Config) -> Result<i32> {
    let sweep_cfg = cfg.sweep();
    let mut series = Vec::new();
    for micro in Micro::ALL {
        eprintln!("running {}-sweep ...", micro.name());
        let cells = sweep::run_micro_sweep(
            &sweep_cfg,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            micro,
        )?;
        series.push((micro, cells));
    }
    println!("{}", report::figure2(&series, Some(&cfg.out))?);
    println!("(raw series: {}/figure2.csv)", cfg.out.display());
    Ok(0)
}

fn cmd_motivation(cfg: &Config) -> Result<i32> {
    let sweep_cfg = cfg.sweep();
    let kinds = [
        AllocatorKind::Malloc,
        AllocatorKind::Memalign,
        AllocatorKind::HugePages,
        AllocatorKind::Puma(FitPolicy::WorstFit),
    ];
    let rows = sweep::run_motivation(&sweep_cfg, &kinds)?;
    println!("{}", report::motivation(&rows, Some(&cfg.out))?);
    println!("(raw series: {}/motivation.csv)", cfg.out.display());
    Ok(0)
}

fn cmd_churn(cfg: &Config, tenants: usize, epochs: usize, mode: &str) -> Result<i32> {
    let mk = |compact: bool| crate::workloads::churn::ChurnConfig {
        tenants,
        epochs,
        compact,
        huge_pages: cfg.huge_pages,
        puma_pages: cfg.puma_pages.max(2),
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        ..Default::default()
    };
    let run = |compact: bool| -> Result<crate::workloads::churn::ChurnResult> {
        crate::workloads::churn::run(cfg.scheme.clone(), &mk(compact))
    };
    let text = match mode {
        "off" => report::churn_runs(&[("off", &run(false)?)], Some(&cfg.out))?,
        "on" => report::churn_runs(&[("on", &run(true)?)], Some(&cfg.out))?,
        "both" => {
            eprintln!("running compaction-off ...");
            let off = run(false)?;
            eprintln!("running compaction-on ...");
            let on = run(true)?;
            report::churn(&off, Some(&on), Some(&cfg.out))?
        }
        other => bail!("unknown --mode {other:?} (off|on|both)"),
    };
    println!("{text}");
    println!("(raw series: {}/churn.csv)", cfg.out.display());
    Ok(0)
}

fn cmd_serve(
    cfg: &Config,
    tenants: usize,
    ops: usize,
    quantum: u64,
    alloc: Option<AllocatorKind>,
) -> Result<i32> {
    let scfg = crate::workloads::serve::ServeConfig {
        tenants,
        ops_per_tenant: ops,
        quantum,
        huge_pages: cfg.huge_pages,
        puma_pages: cfg.puma_pages.max(2),
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        ..Default::default()
    };
    let kinds: Vec<AllocatorKind> = match alloc {
        Some(k) => vec![k],
        None => vec![
            AllocatorKind::Malloc,
            AllocatorKind::Puma(FitPolicy::WorstFit),
        ],
    };
    eprintln!(
        "running serve study: {} tenant(s) x {} op(s), DRR quantum {} \
         row(s), {} allocator(s) ...",
        scfg.tenants,
        scfg.ops_per_tenant,
        scfg.quantum,
        kinds.len()
    );
    let results =
        crate::workloads::serve::sweep(&cfg.scheme, &scfg, &kinds)?;
    for r in &results {
        anyhow::ensure!(
            r.identical,
            "{}: DRR and back-to-back schedules diverged",
            r.allocator
        );
    }
    println!("{}", report::serve(&results, Some(&cfg.out))?);
    println!("(raw series: {}/serve.csv)", cfg.out.display());
    Ok(0)
}

fn boot_from(cfg: &Config) -> Result<System> {
    System::boot(SystemConfig {
        scheme: cfg.scheme.clone(),
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: cfg.artifacts.clone(),
        ..Default::default()
    })
}

/// Deterministic mixed-op batch behind `trace` and `stats`: two source
/// columns and two destinations, AND/OR/XOR/COPY/NOT/ZERO with real
/// hazards between them (so the batch splits into several waves) and
/// one ragged-length op whose partial trailing row exercises the
/// fallback path — enough to light up every metric and trace lane.
fn run_trace_workload(
    sys: &mut System,
    puma_pages: usize,
) -> Result<crate::coordinator::BatchReport> {
    use crate::pud::isa::{BulkRequest, PudOp};
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let size = 4 * row;
    let pid = sys.spawn();
    let mut alloc =
        AllocatorKind::Puma(FitPolicy::WorstFit).build(sys, puma_pages)?;
    let a = sys.alloc(alloc.as_mut(), pid, size)?;
    let b = sys.alloc_align(alloc.as_mut(), pid, size, a)?;
    let c = sys.alloc_align(alloc.as_mut(), pid, size, a)?;
    let d = sys.alloc_align(alloc.as_mut(), pid, size, a)?;
    let fill = |seed: u8| -> Vec<u8> {
        (0..size)
            .map(|i| (i as u8).wrapping_mul(31).wrapping_add(seed))
            .collect()
    };
    sys.write_virt(pid, a, &fill(0x11))?;
    sys.write_virt(pid, b, &fill(0x7C))?;
    let reqs = [
        BulkRequest::new(PudOp::And, c, vec![a, b], size),
        BulkRequest::new(PudOp::Or, d, vec![a, b], size),
        BulkRequest::new(PudOp::Xor, c, vec![a, b], size - row / 2),
        BulkRequest::new(PudOp::Copy, d, vec![c], size),
        BulkRequest::new(PudOp::Not, c, vec![a], size),
        BulkRequest::new(PudOp::Zero, d, vec![], size),
    ];
    for req in reqs {
        sys.enqueue(pid, req);
    }
    sys.flush(pid)
}

fn cmd_trace(cfg: &Config, export: Option<&str>) -> Result<i32> {
    let mut sys = boot_from(cfg)?;
    sys.coord.obs.tracer.set_enabled(true);
    eprintln!("running mixed-op batch with the wave tracer enabled ...");
    let report = run_trace_workload(&mut sys, cfg.puma_pages.max(2))?;
    let tracer = &sys.coord.obs.tracer;
    println!(
        "waves traced  {} ({} dropped, ring capacity {})",
        tracer.len(),
        tracer.dropped,
        tracer.capacity()
    );
    println!(
        "batch         {} op(s) in {} wave(s), {:.2} ops/wave",
        report.per_op_ns.len(),
        report.waves,
        sys.coord.pipeline.ops_per_wave()
    );
    println!(
        "sim time      {} bank-parallel (vs {} serial-equivalent)",
        fmt_ns(report.elapsed_ns),
        fmt_ns(report.total_ns)
    );
    println!(
        "rows          {} PUD / {} fallback",
        sys.coord.stats.pud_rows, sys.coord.stats.fallback_rows
    );
    match export {
        Some(dir) => {
            let snap = sys.metrics_snapshot();
            let (trace, ddr, prom) = crate::obs::export::export_dir(
                std::path::Path::new(dir),
                sys.coord.obs.tracer.events(),
                &snap,
                &sys.coord.stats,
            )?;
            println!("replay        OK (DDR stream reproduces coordinator totals)");
            println!("wrote         {}", trace.display());
            println!("              {}", ddr.display());
            println!("              {}", prom.display());
            println!(
                "open {} in https://ui.perfetto.dev (one lane per active bank)",
                trace.display()
            );
        }
        None => println!(
            "(pass --export DIR to write trace.json / ddr_stream.txt / metrics.prom)"
        ),
    }
    Ok(0)
}

fn cmd_stats(cfg: &Config) -> Result<i32> {
    let mut sys = boot_from(cfg)?;
    eprintln!("running mixed-op batch to populate the registry ...");
    run_trace_workload(&mut sys, cfg.puma_pages.max(2))?;
    let snap = sys.metrics_snapshot();
    // stdout carries only the Prometheus text so it can be piped
    print!("{}", crate::obs::export::prometheus(&snap));
    Ok(0)
}

fn cmd_micro(
    cfg: &Config,
    micro: Micro,
    alloc: AllocatorKind,
    size: u64,
    batched: bool,
) -> Result<i32> {
    let mut sys = boot_from(cfg)?;
    let runner = if batched {
        microbench::run_batched
    } else {
        microbench::run
    };
    let r = runner(
        &mut sys,
        alloc,
        micro,
        size,
        cfg.reps,
        cfg.puma_pages,
        true,
        cfg.seed,
    )
    .context("micro-benchmark run")?;
    println!(
        "{}-{}  size {}  reps {}",
        r.allocator,
        r.micro.name(),
        fmt_bytes(r.size),
        r.reps
    );
    println!(
        "  PUD rows      {} / {} ({:.1}%)",
        r.coord.pud_rows,
        r.coord.pud_rows + r.coord.fallback_rows,
        r.pud_fraction() * 100.0
    );
    println!("  sim time      {}", fmt_ns(r.sim_ns));
    println!("    alloc       {}", fmt_ns(r.alloc.alloc_ns));
    println!("    pud         {}", fmt_ns(r.coord.pud_ns));
    println!("    fallback    {}", fmt_ns(r.coord.fallback_ns));
    println!("  xla           {} dispatches", r.coord.xla_dispatches);
    if batched {
        let p = &sys.coord.pipeline;
        println!(
            "  pipeline      {} wave(s), {:.2} ops/wave, cache {:.1}% hits, \
             {} fallback dispatch unit(s)",
            p.waves,
            p.ops_per_wave(),
            p.extent_cache.percent(),
            p.fallback_dispatches
        );
        println!(
            "  elapsed       {} bank-parallel (vs {} serial-equivalent)",
            fmt_ns(p.elapsed_ns),
            fmt_ns(r.coord.pud_ns + r.coord.fallback_ns)
        );
    }
    println!("  verify        OK (memory image matches oracle)");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let cli = parse_args(&args(&["fig2", "--reps", "2", "--out", "/tmp/x"])).unwrap();
        assert_eq!(cli.command, "fig2");
        assert_eq!(cli.flags["reps"], "2");
        assert_eq!(cli.flags["out"], "/tmp/x");
    }

    #[test]
    fn bare_flags_are_true() {
        let cli = parse_args(&args(&["info", "--verbose"])).unwrap();
        assert_eq!(cli.flags["verbose"], "true");
    }

    #[test]
    fn defaults_to_help() {
        let cli = parse_args(&[]).unwrap();
        assert_eq!(cli.command, "help");
    }

    #[test]
    fn rejects_double_positional() {
        assert!(parse_args(&args(&["a", "b"])).is_err());
    }

    #[test]
    fn alloc_and_micro_names() {
        assert!(matches!(parse_alloc("puma").unwrap(), AllocatorKind::Puma(_)));
        assert_eq!(parse_alloc("malloc").unwrap(), AllocatorKind::Malloc);
        assert!(parse_alloc("slab").is_err());
        assert_eq!(parse_micro("aand").unwrap(), Micro::Aand);
        assert!(parse_micro("sort").is_err());
    }

    #[test]
    fn build_config_applies_overrides() {
        let cli = parse_args(&args(&[
            "micro", "--micro", "copy", "--alloc", "malloc", "--size", "1KiB",
            "--reps", "7",
        ]))
        .unwrap();
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.reps, 7);
    }

    #[test]
    fn batch_flag_is_command_specific_not_config() {
        let cli =
            parse_args(&args(&["micro", "--batch", "--size", "1KiB"])).unwrap();
        assert_eq!(cli.flags["batch"], "true");
        // must not be rejected as an unknown config key
        build_config(&cli).unwrap();
    }

    #[test]
    fn churn_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "churn", "--tenants", "2", "--epochs", "3", "--mode", "off",
        ]))
        .unwrap();
        assert_eq!(cli.flags["mode"], "off");
        // must not be rejected as unknown config keys
        build_config(&cli).unwrap();
    }

    #[test]
    fn analytics_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "analytics", "--widths", "4,8", "--elems", "4096", "--threshold",
            "0.25", "--alloc", "puma", "--puma_pages", "4", "--shards", "1,4",
        ]))
        .unwrap();
        assert_eq!(cli.flags["widths"], "4,8");
        assert_eq!(cli.flags["shards"], "1,4");
        // widths/elems/threshold/alloc/shards must not be rejected as
        // config keys
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.puma_pages, 4);
    }

    #[test]
    fn query_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "query", "--rows", "4096", "--width", "4", "--groups", "4",
            "--build_keys", "8", "--k", "64", "--shards", "2", "--alloc",
            "puma", "--puma_pages", "4",
        ]))
        .unwrap();
        assert_eq!(cli.flags["rows"], "4096");
        assert_eq!(cli.flags["k"], "64");
        // rows/width/groups/build_keys/k must not be rejected as
        // config keys
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.puma_pages, 4);
    }

    #[test]
    fn filter_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "filter", "--clauses", "2", "--alloc", "puma", "--puma_pages", "4",
        ]))
        .unwrap();
        assert_eq!(cli.flags["clauses"], "2");
        // clauses/alloc must not be rejected as unknown config keys
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.puma_pages, 4);
    }

    #[test]
    fn serve_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "serve", "--tenants", "8", "--ops", "6", "--quantum", "4",
            "--alloc", "puma", "--puma_pages", "4",
        ]))
        .unwrap();
        assert_eq!(cli.flags["ops"], "6");
        assert_eq!(cli.flags["quantum"], "4");
        // tenants/ops/quantum/alloc must not be rejected as config keys
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.puma_pages, 4);
    }

    #[test]
    fn lint_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "lint", "--alloc", "puma", "--json", "/tmp/lint.json",
            "--puma_pages", "4",
        ]))
        .unwrap();
        assert_eq!(cli.flags["json"], "/tmp/lint.json");
        // alloc/json must not be rejected as unknown config keys
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.puma_pages, 4);
    }

    #[test]
    fn trace_flags_are_command_specific_not_config() {
        let cli = parse_args(&args(&[
            "trace", "--export", "/tmp/t", "--puma_pages", "4",
        ]))
        .unwrap();
        assert_eq!(cli.flags["export"], "/tmp/t");
        // export must not be rejected as an unknown config key
        let cfg = build_config(&cli).unwrap();
        assert_eq!(cfg.puma_pages, 4);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&args(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(&args(&["help"])).unwrap(), 0);
    }
}
