//! Tiny self-contained stderr logger (no external `log` crate — see
//! the offline-dependency doctrine in `util/mod.rs`).
//!
//! The level comes from `PUMA_LOG` (`off|error|warn|info|debug|trace`,
//! default `info`). Unrecognized values fall back to `info` but emit a
//! one-time stderr warning instead of failing silently. Call sites use
//! the [`crate::puma_warn!`]/[`crate::puma_info!`]/[`crate::puma_debug!`]
//! macros, which stamp each line with `module_path!()` so the tracer,
//! `puma stats`, and ad-hoc logging all share one naming scheme.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Log severities, most severe first. `Off` suppresses everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Off,
            1 => Level::Error,
            2 => Level::Warn,
            4 => Level::Debug,
            5 => Level::Trace,
            _ => Level::Info,
        }
    }

    fn label(self) -> &'static str {
        match self {
            Level::Off => "OFF",
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static WARN_ONCE: Once = Once::new();

/// Parse a `PUMA_LOG` value. `Ok` carries the level; `Err` carries the
/// unrecognized input (caller decides how loudly to complain).
pub fn parse_level(raw: &str) -> Result<Level, String> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "off" | "none" | "0" => Ok(Level::Off),
        "error" => Ok(Level::Error),
        "warn" | "warning" => Ok(Level::Warn),
        "info" | "" => Ok(Level::Info),
        "debug" => Ok(Level::Debug),
        "trace" => Ok(Level::Trace),
        other => Err(other.to_string()),
    }
}

/// Resolve the level from an optional `PUMA_LOG` value without touching
/// the process environment (pure; unit-testable). The second element is
/// the one-time warning to emit for unrecognized input, if any.
pub fn level_from_env(raw: Option<&str>) -> (Level, Option<String>) {
    match raw {
        None => (Level::Info, None),
        Some(v) => match parse_level(v) {
            Ok(level) => (level, None),
            Err(bad) => (
                Level::Info,
                Some(format!(
                    "[WARN  puma::util::logging] unrecognized PUMA_LOG={bad:?} \
                     (expected off|error|warn|info|debug|trace); using info"
                )),
            ),
        },
    }
}

/// Install the level from `PUMA_LOG`. Safe to call repeatedly; the
/// unrecognized-value warning prints at most once per process.
pub fn init() {
    let raw = std::env::var("PUMA_LOG").ok();
    let (level, warning) = level_from_env(raw.as_deref());
    if let Some(w) = warning {
        WARN_ONCE.call_once(|| eprintln!("{w}"));
    }
    set_level(level);
}

/// Override the level programmatically (tests, CLI flags).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The currently installed level.
pub fn level() -> Level {
    Level::from_u8(MAX_LEVEL.load(Ordering::Relaxed))
}

/// Would a record at `at` be emitted?
pub fn enabled(at: Level) -> bool {
    at != Level::Off && at <= level()
}

/// Emit one record. Prefer the `puma_*!` macros, which supply
/// `module_path!()` as the target.
pub fn log(at: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(at) {
        eprintln!("[{:<5} {}] {}", at.label(), target, args);
    }
}

/// Log at `Error` with the calling module as the target.
#[macro_export]
macro_rules! puma_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at `Warn` with the calling module as the target.
#[macro_export]
macro_rules! puma_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at `Info` with the calling module as the target.
#[macro_export]
macro_rules! puma_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at `Debug` with the calling module as the target.
#[macro_export]
macro_rules! puma_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init(); // second call must not panic
        crate::puma_info!("logging smoke test");
    }

    #[test]
    fn recognized_levels_parse() {
        assert_eq!(parse_level("off"), Ok(Level::Off));
        assert_eq!(parse_level("ERROR"), Ok(Level::Error));
        assert_eq!(parse_level(" warn "), Ok(Level::Warn));
        assert_eq!(parse_level("info"), Ok(Level::Info));
        assert_eq!(parse_level("debug"), Ok(Level::Debug));
        assert_eq!(parse_level("trace"), Ok(Level::Trace));
    }

    #[test]
    fn unrecognized_value_warns_and_falls_back_to_info() {
        let (level, warning) = level_from_env(Some("verbose"));
        assert_eq!(level, Level::Info);
        let w = warning.expect("unrecognized value must produce a warning");
        assert!(w.contains("verbose"), "{w}");
        assert!(w.contains("PUMA_LOG"), "{w}");
    }

    #[test]
    fn off_suppresses_everything() {
        let (level, warning) = level_from_env(Some("off"));
        assert_eq!(level, Level::Off);
        assert!(warning.is_none());
        let prev = super::level();
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        assert!(!enabled(Level::Off));
        set_level(prev);
    }

    #[test]
    fn unset_env_is_plain_info() {
        assert_eq!(level_from_env(None), (Level::Info, None));
    }
}
