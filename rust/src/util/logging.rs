//! Tiny stderr logger backing the `log` facade.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:<5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `PUMA_LOG` (error|warn|info|
/// debug|trace), default `info`. Safe to call repeatedly.
pub fn init() {
    let level = match std::env::var("PUMA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = Box::new(StderrLogger { level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init(); // second call must not panic
        log::info!("logging smoke test");
    }
}
