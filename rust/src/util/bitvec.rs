//! Compact bit vector used by the frame allocator and workload bitmaps.

/// Fixed-capacity bit vector over u64 words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All-zeros bit vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// All-ones bit vector of `len` bits.
    pub fn ones(len: usize) -> Self {
        let mut v = Self {
            words: vec![u64::MAX; len.div_ceil(64)],
            len,
        };
        v.clear_tail();
        v
    }

    fn clear_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "bit index {i} out of range {}", self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len, "bit index {i} out of range {}", self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Index of the first zero bit, if any.
    pub fn first_zero(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != u64::MAX {
                let b = (!w).trailing_zeros() as usize;
                let i = wi * 64 + b;
                if i < self.len {
                    return Some(i);
                }
            }
        }
        None
    }

    /// Index of the first set bit at or after `from`, if any.
    pub fn next_one(&self, from: usize) -> Option<usize> {
        if from >= self.len {
            return None;
        }
        let mut wi = from / 64;
        let mut w = self.words[wi] & (u64::MAX << (from % 64));
        loop {
            if w != 0 {
                let i = wi * 64 + w.trailing_zeros() as usize;
                return (i < self.len).then_some(i);
            }
            wi += 1;
            if wi >= self.words.len() {
                return None;
            }
            w = self.words[wi];
        }
    }

    /// In-place bitwise AND with another vector of the same length.
    pub fn and_with(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= *b;
        }
    }

    /// In-place bitwise OR with another vector of the same length.
    pub fn or_with(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
    }

    /// Raw words (little-endian bit order), for bulk I/O into DRAM rows.
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_ones() {
        let z = BitVec::zeros(130);
        assert_eq!(z.count_ones(), 0);
        assert_eq!(z.len(), 130);
        let o = BitVec::ones(130);
        assert_eq!(o.count_ones(), 130);
        assert!(o.get(129));
    }

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(200);
        for i in (0..200).step_by(7) {
            v.set(i, true);
        }
        for i in 0..200 {
            assert_eq!(v.get(i), i % 7 == 0, "bit {i}");
        }
        v.set(7, false);
        assert!(!v.get(7));
    }

    #[test]
    fn first_zero_scans_words() {
        let mut v = BitVec::ones(100);
        assert_eq!(v.first_zero(), None);
        v.set(70, false);
        assert_eq!(v.first_zero(), Some(70));
        v.set(3, false);
        assert_eq!(v.first_zero(), Some(3));
    }

    #[test]
    fn next_one_across_word_boundary() {
        let mut v = BitVec::zeros(150);
        v.set(5, true);
        v.set(130, true);
        assert_eq!(v.next_one(0), Some(5));
        assert_eq!(v.next_one(6), Some(130));
        assert_eq!(v.next_one(131), None);
        assert_eq!(v.next_one(149), None);
    }

    #[test]
    fn tail_bits_do_not_leak() {
        let v = BitVec::ones(65);
        assert_eq!(v.count_ones(), 65);
        assert_eq!(v.first_zero(), None);
    }

    #[test]
    fn and_or_with() {
        let mut a = BitVec::zeros(10);
        let mut b = BitVec::zeros(10);
        a.set(1, true);
        a.set(2, true);
        b.set(2, true);
        b.set(3, true);
        let mut and = a.clone();
        and.and_with(&b);
        assert!(!and.get(1) && and.get(2) && !and.get(3));
        a.or_with(&b);
        assert!(a.get(1) && a.get(2) && a.get(3));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn oob_get_panics() {
        BitVec::zeros(8).get(8);
    }
}
