//! Markdown/ASCII table rendering for reports and bench output.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder that renders GitHub-flavored markdown (which
/// also reads fine as plain ASCII in a terminal).
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let aligns = vec![Align::Right; headers.len()];
        Self {
            headers,
            aligns,
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (defaults to Right; first column is
    /// usually a label — call `left(0)`).
    pub fn left(mut self, col: usize) -> Self {
        self.aligns[col] = Align::Left;
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| {
            let mut line = String::from("|");
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match aligns[i] {
                    Align::Left => {
                        line.push(' ');
                        line.push_str(cell);
                        line.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad + 1));
                        line.push_str(cell);
                        line.push(' ');
                    }
                }
                line.push('|');
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            match self.aligns[i] {
                Align::Left => out.push_str(&format!(":{}|", "-".repeat(w + 1))),
                Align::Right => out.push_str(&format!("{}:|", "-".repeat(w + 1))),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }
}

/// Format a float with sensible precision for tables.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(vec!["name", "value"]).left(0);
        t.row(vec!["alpha", "1"]);
        t.row(vec!["b", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[1].starts_with("|:"));
        assert!(lines[2].contains("alpha"));
        // all lines same width
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn mismatched_row_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fnum_precision() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(3.14159), "3.14");
        assert_eq!(fnum(42.123), "42.1");
        assert_eq!(fnum(1234.6), "1235");
    }
}
