//! Deterministic PRNGs for simulation and testing.
//!
//! `Pcg64` (PCG-XSL-RR 128/64) for general simulation use and
//! `SplitMix64` for cheap seeding/derivation. Determinism matters:
//! every experiment in EXPERIMENTS.md records its seed, and the
//! property-testing framework replays failures from the reported seed.

/// SplitMix64 — tiny, solid generator used for seeding and derivation.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed both the state and the stream from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        // Rejection-free path for powers of two.
        if bound.is_power_of_two() {
            return self.next_u64() & (bound - 1);
        }
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
}

/// Entropy-seeded generator for non-reproducible contexts (CLI default
/// seeds); experiments always pass explicit seeds. Std-only (the
/// offline build has no `getrandom` crate): read `/dev/urandom`, fall
/// back to the clock where that fails (non-Linux dev hosts).
pub fn from_entropy() -> Pcg64 {
    use std::io::Read;
    let mut seed = [0u8; 8];
    let filled = std::fs::File::open("/dev/urandom")
        .and_then(|mut f| f.read_exact(&mut seed))
        .is_ok();
    if !filled {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        seed = (t.as_nanos() as u64).to_le_bytes();
    }
    Pcg64::new(u64::from_le_bytes(seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(1);
        let mut c = Pcg64::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg64::new(7);
        for bound in [1u64, 2, 3, 10, 100, 1 << 20, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut rng = Pcg64::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[rng.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Pcg64::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = rng.range(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg64::new(13);
        for _ in 0..1000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut rng = Pcg64::new(19);
        let mut buf = [0u8; 13];
        rng.fill_bytes(&mut buf);
        // all-zeros after fill is astronomically unlikely
        assert!(buf.iter().any(|&b| b != 0));
    }
}
