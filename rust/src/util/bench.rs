//! Wall-clock micro-benchmark harness (criterion is not in the offline
//! vendor set — DESIGN.md §7).
//!
//! Used by every `[[bench]]` target (`harness = false`): warmup, fixed
//! iteration count or time budget, and a [`Summary`] over per-iteration
//! wall-clock samples. Output format is one line per benchmark plus an
//! optional markdown table, so `cargo bench` logs read like criterion's.

use std::time::{Duration, Instant};

use super::stats::Summary;
use super::units::fmt_ns;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: u32,
    pub min_iters: u32,
    /// Stop adding iterations once this much time has been spent
    /// (after `min_iters` is satisfied).
    pub max_time: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_time: Duration::from_secs(3),
        }
    }
}

impl BenchOpts {
    /// Fast settings for quick smoke runs (`PUMA_BENCH_FAST=1`).
    pub fn fast() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_time: Duration::from_millis(300),
        }
    }

    /// Pick opts from the environment (used by all bench mains so CI
    /// can run benches quickly).
    pub fn from_env() -> Self {
        if std::env::var("PUMA_BENCH_FAST").is_ok() {
            Self::fast()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark: wall-clock summary in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub wall_ns: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.wall_ns.mean),
            fmt_ns(self.wall_ns.p50),
            fmt_ns(self.wall_ns.p99),
            self.wall_ns.n
        )
    }
}

/// Run `f` under the harness and report per-iteration wall time.
/// `f` receives the iteration index; use it to vary seeds if needed.
pub fn bench(name: &str, opts: &BenchOpts, mut f: impl FnMut(u32)) -> BenchResult {
    for i in 0..opts.warmup_iters {
        f(i);
    }
    let mut samples = Vec::new();
    let budget_start = Instant::now();
    let mut i = 0;
    loop {
        let t0 = Instant::now();
        f(i);
        samples.push(t0.elapsed().as_nanos() as f64);
        i += 1;
        if i >= opts.min_iters && budget_start.elapsed() >= opts.max_time {
            break;
        }
        // hard cap to keep pathological cases bounded
        if i >= 100_000 {
            break;
        }
    }
    let res = BenchResult {
        name: name.to_string(),
        wall_ns: Summary::of(&samples),
    };
    println!("{}", res.line());
    res
}

/// Black-box helper to prevent the optimizer from deleting work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_min_iters() {
        let opts = BenchOpts {
            warmup_iters: 1,
            min_iters: 5,
            max_time: Duration::ZERO,
        };
        let mut count = 0;
        let res = bench("t", &opts, |_| count += 1);
        assert_eq!(res.wall_ns.n, 5);
        assert_eq!(count, 6); // warmup + 5
    }

    #[test]
    fn respects_time_budget() {
        let opts = BenchOpts {
            warmup_iters: 0,
            min_iters: 1,
            max_time: Duration::from_millis(30),
        };
        let res = bench("sleepy", &opts, |_| {
            std::thread::sleep(Duration::from_millis(10))
        });
        // ~3-4 iterations fit the budget; certainly < 20
        assert!(res.wall_ns.n >= 1 && res.wall_ns.n < 20);
    }

    #[test]
    fn fast_opts_from_env() {
        // from_env without the var set == default
        let d = BenchOpts::from_env();
        assert_eq!(d.min_iters, BenchOpts::default().min_iters);
    }
}
