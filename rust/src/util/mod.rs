//! Support utilities.
//!
//! The offline vendor set ships only the `xla` crate's dependency
//! closure, so the helpers a project would normally pull from
//! crates.io (`rand`, `criterion`, `prettytable`, `csv`, …) are
//! implemented here (see DESIGN.md §7).

pub mod bench;
pub mod bitvec;
pub mod chart;
pub mod csvio;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
