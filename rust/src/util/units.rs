//! Size parsing and formatting (bits and bytes).
//!
//! The paper sweeps allocation sizes "from 2000 bits to 6 Mb", i.e. it
//! mixes bit- and byte-denominated sizes; the CLI and the sweep
//! configs accept both (`2000b`, `2Kib`, `8KiB`, `2MB`, `1GiB`).

use anyhow::{anyhow, bail, Result};

/// Parse a size string into **bytes**.
///
/// Suffix grammar (case-sensitive on the final `b`/`B`):
/// * `B`, `KB`/`KiB`, `MB`/`MiB`, `GB`/`GiB` — bytes (binary multiples;
///   the paper's sizes are powers of two so KB == KiB here)
/// * `b`, `Kb`/`Kib`, `Mb`/`Mib`, `Gb`/`Gib` — **bits**, rounded up to
///   whole bytes
/// * bare number — bytes
pub fn parse_size(s: &str) -> Result<u64> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty size string");
    }
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '_'))
        .unwrap_or(s.len());
    let (num, suffix) = s.split_at(split);
    let num: u64 = num
        .replace('_', "")
        .parse()
        .map_err(|e| anyhow!("bad size number {s:?}: {e}"))?;
    let (mult, bits) = match suffix.trim() {
        "" | "B" => (1, false),
        "b" | "bit" | "bits" => (1, true),
        "KB" | "KiB" | "K" => (1 << 10, false),
        "Kb" | "Kib" => (1 << 10, true),
        "MB" | "MiB" | "M" => (1 << 20, false),
        "Mb" | "Mib" => (1 << 20, true),
        "GB" | "GiB" | "G" => (1 << 30, false),
        "Gb" | "Gib" => (1 << 30, true),
        other => bail!("unknown size suffix {other:?} in {s:?}"),
    };
    let raw = num
        .checked_mul(mult)
        .ok_or_else(|| anyhow!("size overflow: {s:?}"))?;
    Ok(if bits { raw.div_ceil(8) } else { raw })
}

/// Format a byte count with a binary suffix (`12.5 KiB`).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else if (v - v.round()).abs() < 1e-9 {
        format!("{} {}", v.round() as u64, UNITS[u])
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a nanosecond count human-readably (`1.25 ms`).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bytes() {
        assert_eq!(parse_size("0").unwrap(), 0);
        assert_eq!(parse_size("123").unwrap(), 123);
        assert_eq!(parse_size("4KB").unwrap(), 4096);
        assert_eq!(parse_size("4KiB").unwrap(), 4096);
        assert_eq!(parse_size("2MB").unwrap(), 2 << 20);
        assert_eq!(parse_size("1GiB").unwrap(), 1 << 30);
        assert_eq!(parse_size("1_024").unwrap(), 1024);
    }

    #[test]
    fn parses_bits_rounding_up() {
        assert_eq!(parse_size("2000b").unwrap(), 250);
        assert_eq!(parse_size("2001b").unwrap(), 251);
        assert_eq!(parse_size("2Kib").unwrap(), 256);
        // the paper's top size: 6 Mb = 6 * 2^20 bits = 786432 bytes
        assert_eq!(parse_size("6Mb").unwrap(), 6 * (1 << 20) / 8);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_size("").is_err());
        assert!(parse_size("abc").is_err());
        assert!(parse_size("12XB").is_err());
        assert!(parse_size("999999999999GB").is_err());
    }

    #[test]
    fn formats_bytes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(4096), "4 KiB");
        assert_eq!(fmt_bytes(786432), "768 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3 MiB");
        assert_eq!(fmt_bytes(1536), "1.5 KiB");
    }

    #[test]
    fn formats_ns() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn roundtrip_pow2() {
        for shift in 0..30 {
            let n = 1u64 << shift;
            let s = fmt_bytes(n);
            // formatted power-of-two sizes re-parse to the same value
            let compact: String = s.split_whitespace().collect();
            assert_eq!(parse_size(&compact).unwrap(), n, "{s}");
        }
    }
}
