//! Minimal CSV writing (RFC-4180 quoting) for experiment outputs.
//!
//! Every bench target writes its raw series as CSV next to the
//! rendered table so figures can be regenerated outside the terminal.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// In-memory CSV document.
#[derive(Debug, Default, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn quote(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl Csv {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "CSV row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|f| quote(f))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|f| quote(f)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)
                .with_context(|| format!("mkdir -p {}", dir.display()))?;
        }
        let mut f = fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(self.render().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_plain() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.row(vec!["1", "2"]);
        assert_eq!(c.render(), "a,b\n1,2\n");
    }

    #[test]
    fn quotes_specials() {
        let mut c = Csv::new(vec!["x"]);
        c.row(vec!["has,comma"]);
        c.row(vec!["has\"quote"]);
        let s = c.render();
        assert!(s.contains("\"has,comma\""));
        assert!(s.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn width_mismatch_panics() {
        Csv::new(vec!["a", "b"]).row(vec!["1"]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("puma_csv_test");
        let path = dir.join("out.csv");
        let mut c = Csv::new(vec!["k"]);
        c.row(vec!["v"]);
        c.write(&path).unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "k\nv\n");
        let _ = fs::remove_dir_all(dir);
    }
}
