//! ASCII charts for terminal-rendered figures.
//!
//! `report/` uses these to draw the paper's Figure 2 (speedup vs
//! allocation size, one series per micro-benchmark) directly in the
//! terminal, alongside the CSV the plots can be regenerated from.

/// A horizontal bar chart: one labeled bar per entry, scaled to
/// `width` characters at the maximum value.
pub fn bar_chart(entries: &[(String, f64)], width: usize) -> String {
    if entries.is_empty() {
        return String::new();
    }
    let maxv = entries
        .iter()
        .map(|(_, v)| *v)
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1e-12);
    let label_w = entries.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, v) in entries {
        let n = ((v / maxv) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!(
            "{label:<label_w$} | {}{} {v:.2}\n",
            "#".repeat(n),
            " ".repeat(width - n.min(width)),
        ));
    }
    out
}

/// Multi-series line chart on a character grid. X positions are evenly
/// spaced sample indices (the sweeps are log-spaced in size, so even
/// spacing == log axis). Each series gets a distinct glyph.
pub fn line_chart(
    x_labels: &[String],
    series: &[(String, Vec<f64>)],
    height: usize,
) -> String {
    if series.is_empty() || series[0].1.is_empty() {
        return String::new();
    }
    let glyphs = ['*', 'o', '+', 'x', '@', '%'];
    let npts = series[0].1.len();
    let maxv = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1e-12);
    let col_w = 6usize;
    let width = npts * col_w;
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for (i, &y) in ys.iter().enumerate() {
            let row = if maxv <= 0.0 {
                height - 1
            } else {
                let frac = (y / maxv).clamp(0.0, 1.0);
                height - 1 - ((frac * (height - 1) as f64).round() as usize)
            };
            let col = i * col_w + col_w / 2;
            grid[row][col] = g;
        }
    }
    let mut out = String::new();
    for (ri, row) in grid.iter().enumerate() {
        let yv = maxv * (height - 1 - ri) as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>8.1} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>8} +{}\n", "", "-".repeat(width)));
    let mut xaxis = format!("{:>9}", "");
    for l in x_labels.iter().take(npts) {
        xaxis.push_str(&format!("{l:^col_w$}"));
    }
    out.push_str(&xaxis);
    out.push('\n');
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {name}", glyphs[i % glyphs.len()]))
        .collect();
    out.push_str(&format!("{:>9}legend: {}\n", "", legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart(
            &[("a".into(), 10.0), ("bb".into(), 5.0)],
            20,
        );
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].matches('#').count(), 20);
        assert_eq!(lines[1].matches('#').count(), 10);
        // labels padded to equal width
        assert!(lines[0].starts_with("a  |") || lines[0].starts_with("a "));
    }

    #[test]
    fn bar_chart_empty() {
        assert_eq!(bar_chart(&[], 10), "");
    }

    #[test]
    fn line_chart_plots_all_series() {
        // series names avoid the glyph characters so counts are exact
        let s = line_chart(
            &["1".into(), "2".into(), "3".into()],
            &[
                ("rise".into(), vec![1.0, 2.0, 3.0]),
                ("fall".into(), vec![3.0, 2.0, 1.0]),
            ],
            5,
        );
        // later series may overwrite colliding grid cells of earlier
        // ones, so the first series shows >= 2 points (+1 legend glyph)
        assert!(s.matches('*').count() >= 3);
        assert_eq!(s.matches('o').count(), 4); // 3 points + legend glyph
        assert!(s.contains("legend: * rise   o fall"));
    }

    #[test]
    fn line_chart_handles_flat_zero() {
        let s = line_chart(
            &["a".into()],
            &[("z".into(), vec![0.0])],
            3,
        );
        assert!(s.contains('*'));
    }
}
