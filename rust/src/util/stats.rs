//! Summary statistics over f64 samples (mean/std/percentiles).

/// Summary of a sample set. Percentiles use the nearest-rank method on
/// the sorted samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    pub sum: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let n = sorted.len();
        let sum: f64 = sorted.iter().sum();
        let mean = sum / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let pct = |p: f64| -> f64 {
            let rank = ((p / 100.0) * n as f64).ceil() as usize;
            sorted[rank.clamp(1, n) - 1]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
            max: sorted[n - 1],
            sum,
        }
    }
}

/// Online counter for ratios (hits / total) with helpers used by the
/// motivation study (fraction of PUD-executable operations).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HitRate {
    pub hits: u64,
    pub total: u64,
}

impl HitRate {
    pub fn record(&mut self, hit: bool) {
        self.hits += hit as u64;
        self.total += 1;
    }

    pub fn merge(&mut self, other: HitRate) {
        self.hits += other.hits;
        self.total += other.total;
    }

    pub fn ratio(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.hits as f64 / self.total as f64
        }
    }

    pub fn percent(&self) -> f64 {
        self.ratio() * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[3.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn summary_known_values() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        // sample std of 1..=100 is ~29.0115
        assert!((s.std - 29.011491975882016).abs() < 1e-9);
    }

    #[test]
    fn summary_order_invariant() {
        let a = Summary::of(&[5.0, 1.0, 3.0]);
        let b = Summary::of(&[1.0, 3.0, 5.0]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }

    #[test]
    fn hitrate_basic() {
        let mut h = HitRate::default();
        assert_eq!(h.ratio(), 0.0);
        h.record(true);
        h.record(false);
        h.record(true);
        h.record(true);
        assert_eq!(h.hits, 3);
        assert_eq!(h.total, 4);
        assert!((h.percent() - 75.0).abs() < 1e-12);
    }

    #[test]
    fn hitrate_merge() {
        let mut a = HitRate { hits: 1, total: 2 };
        a.merge(HitRate { hits: 3, total: 4 });
        assert_eq!(a, HitRate { hits: 4, total: 6 });
    }
}
