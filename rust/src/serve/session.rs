//! The per-tenant session handle.
//!
//! A [`Session`] owns everything one tenant needs to use the machine
//! and nothing it could use to touch another tenant: a private
//! process address space (the `Pid` stays inside the handle — no
//! caller above this layer threads raw pids), a submission queue the
//! fairness scheduler drains, per-shard scratch pools under a
//! resident-buffer quota, and a DRR weight. The kernel surface
//! (`arith`/`arith_const`/`column_sum`/`column`) mirrors `System`'s
//! layout-polymorphic [`Column`] API one-for-one, with admission
//! control in front: a kernel whose scratch lease would push the
//! session past its quota is refused with a typed
//! [`ServeError::Rejected`] *before* anything is leased, and the
//! tenant recovers by calling [`Session::trim`].

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::alloc::request::AllocRequest;
use crate::alloc::traits::Allocator;
use crate::coordinator::dispatch::BatchReport;
use crate::coordinator::system::{ExprReport, System};
use crate::obs::metrics::HistId;
use crate::os::process::Pid;
use crate::pud::arith::{
    self, ArithOp, Column, LayoutSpec, ProgramKey, ShardedLayout,
    ShardedScratch, VerticalLayout,
};
use crate::pud::isa::BulkRequest;

use super::error::{RejectReason, ServeError};

/// Construction options for one tenant session.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Tenant name — labels metrics (`serve/{name}/op_ns`) and reports.
    pub name: String,
    /// DRR weight: per-round credit is `quantum × weight` rows.
    pub weight: u32,
    /// Max resident scratch buffers across the session's pools; kernel
    /// runs projecting past this are rejected (see module docs).
    pub scratch_quota: usize,
    /// Queue depth beyond which submissions report
    /// `SubmitOutcome::Queued` (soft backpressure).
    pub backpressure: usize,
    /// Hard queue cap beyond which submissions are rejected.
    pub queue_cap: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            name: "tenant".to_string(),
            weight: 1,
            scratch_quota: 64,
            backpressure: 64,
            queue_cap: 256,
        }
    }
}

impl SessionConfig {
    /// A default-config session named `name`.
    pub fn named(name: impl Into<String>) -> Self {
        Self { name: name.into(), ..Self::default() }
    }
}

/// One tenant's handle on the machine (see module docs).
pub struct Session {
    pub(crate) pid: Pid,
    name: String,
    weight: u32,
    scratch_quota: usize,
    pub(crate) backpressure: usize,
    pub(crate) queue_cap: usize,
    /// Requests admitted but not yet executed, drained front-first by
    /// the DRR scheduler (per-tenant FIFO order is preserved).
    pub(crate) queue: VecDeque<BulkRequest>,
    /// Per-shard scratch pools (flat kernels use pool 0).
    pub(crate) pools: ShardedScratch,
    /// DRR deficit counter, in rows.
    pub(crate) deficit: u64,
    /// Per-op simulated latency histogram (`serve/{name}/op_ns`).
    pub(crate) op_hist: HistId,
    /// Simulated completion time of this tenant's latest executed
    /// request, on the owning gateway's clock.
    pub(crate) last_done_ns: f64,
}

impl Session {
    /// Open a session: spawns a private address space and registers
    /// the tenant's latency histogram.
    pub fn open(sys: &mut System, cfg: SessionConfig) -> Session {
        let pid = sys.spawn();
        let op_hist = sys
            .coord
            .obs
            .registry
            .hist(&format!("serve/{}/op_ns", cfg.name));
        Session {
            pid,
            name: cfg.name,
            weight: cfg.weight.max(1),
            scratch_quota: cfg.scratch_quota,
            backpressure: cfg.backpressure,
            queue_cap: cfg.queue_cap,
            queue: VecDeque::new(),
            pools: ShardedScratch::new(),
            deficit: 0,
            op_hist,
            last_done_ns: 0.0,
        }
    }

    /// The tenant's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The tenant's DRR weight.
    pub fn weight(&self) -> u32 {
        self.weight
    }

    /// The session's resident scratch quota.
    pub fn scratch_quota(&self) -> usize {
        self.scratch_quota
    }

    /// Requests admitted but not yet executed.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Scratch buffers currently resident across the session's pools.
    pub fn scratch_resident(&self) -> usize {
        self.pools.resident()
    }

    /// Simulated completion time of the tenant's latest executed
    /// request (gateway clock; 0 until something ran).
    pub fn completed_ns(&self) -> f64 {
        self.last_done_ns
    }

    /// Place one allocation in the session's address space. Placement
    /// failures surface as typed
    /// [`RejectReason::CapacityExhausted`] errors.
    pub fn alloc(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        req: AllocRequest,
    ) -> Result<u64> {
        sys.alloc_with(alloc, self.pid, req).map_err(capacity)
    }

    /// Free an allocation made through [`Session::alloc`].
    pub fn free(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        va: u64,
    ) -> Result<()> {
        sys.free(alloc, self.pid, va)
    }

    /// Write bytes through the session's virtual mapping.
    pub fn write(&self, sys: &mut System, va: u64, data: &[u8]) -> Result<()> {
        sys.write_virt(self.pid, va, data)
    }

    /// Read bytes through the session's virtual mapping.
    pub fn read(&self, sys: &mut System, va: u64, len: u64) -> Result<Vec<u8>> {
        sys.read_virt(self.pid, va, len)
    }

    /// Allocate a fresh [`Column`] under placement `spec`.
    pub fn alloc_column(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        width: u32,
        elems: usize,
        spec: LayoutSpec,
    ) -> Result<Column> {
        match spec {
            LayoutSpec::Flat => {
                VerticalLayout::alloc(sys, alloc, self.pid, width, elems)
                    .map(Column::Flat)
            }
            LayoutSpec::Sharded(n) => {
                ShardedLayout::alloc(sys, alloc, self.pid, width, elems, n)
                    .map(Column::Sharded)
            }
        }
        .map_err(capacity)
    }

    /// Allocate a `width`-bit column shaped and placed like `like`
    /// (flat: co-located with `like`'s planes; sharded: shard-for-shard
    /// on `like`'s anchors) — the alignment-chaining pattern every
    /// kernel operand/destination pair uses.
    pub fn alloc_column_like(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        width: u32,
        like: &Column,
    ) -> Result<Column> {
        match like {
            Column::Flat(l) => VerticalLayout::alloc_with_hint(
                sys,
                alloc,
                self.pid,
                width,
                l.elems(),
                l.hint(),
            )
            .map(Column::Flat),
            Column::Sharded(s) => {
                ShardedLayout::alloc_like(sys, alloc, self.pid, width, s)
                    .map(Column::Sharded)
            }
        }
        .map_err(capacity)
    }

    /// Return a column's planes to the allocator.
    pub fn free_column(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        col: &Column,
    ) -> Result<()> {
        match col {
            Column::Flat(l) => l.free(sys, alloc, self.pid),
            Column::Sharded(s) => s.free(sys, alloc, self.pid),
        }
    }

    /// Transpose `values` into `col`'s planes.
    pub fn store_column(
        &self,
        sys: &mut System,
        col: &Column,
        values: &[u64],
    ) -> Result<()> {
        match col {
            Column::Flat(l) => l.store(sys, self.pid, values),
            Column::Sharded(s) => s.store(sys, self.pid, values),
        }
    }

    /// Read `col`'s planes back and untranspose.
    pub fn load_column(
        &self,
        sys: &mut System,
        col: &Column,
    ) -> Result<Vec<u64>> {
        match col {
            Column::Flat(l) => l.load(sys, self.pid),
            Column::Sharded(s) => s.load(sys, self.pid),
        }
    }

    /// The session's resident cached column (see [`System::column`]).
    #[allow(clippy::too_many_arguments)]
    pub fn column(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
        spec: LayoutSpec,
    ) -> Result<Column> {
        sys.column(alloc, self.pid, id, version, width, values, spec)
            .map_err(capacity)
    }

    /// Run `op` over the session's columns (see [`System::arith`]),
    /// with scratch-quota admission in front.
    #[allow(clippy::too_many_arguments)]
    pub fn arith(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        op: ArithOp,
        a: &Column,
        b: Option<&Column>,
        dst: &Column,
    ) -> Result<ExprReport> {
        self.admit_kernel(sys, ProgramKey::Kernel(op, a.width()), 0, a)?;
        sys.arith(alloc, self.pid, op, a, b, dst, &mut self.pools)
    }

    /// Run `op` with a constant rhs (see [`System::arith_const`]),
    /// with scratch-quota admission in front.
    #[allow(clippy::too_many_arguments)]
    pub fn arith_const(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        op: ArithOp,
        rhs: u64,
        a: &Column,
        dst: &Column,
    ) -> Result<ExprReport> {
        let key = ProgramKey::KernelConst(
            op,
            a.width(),
            rhs & arith::width_mask(a.width()),
        );
        self.admit_kernel(sys, key, 0, a)?;
        sys.arith_const(alloc, self.pid, op, rhs, a, dst, &mut self.pools)
    }

    /// Filter-then-sum over the session's columns (see
    /// [`System::column_sum`]), with scratch-quota admission in front
    /// of the masked path (the unmasked path leases nothing).
    pub fn column_sum(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        values: &Column,
        mask: Option<&Column>,
    ) -> Result<(u128, Option<ExprReport>)> {
        if mask.is_some() {
            self.admit_kernel(
                sys,
                ProgramKey::MaskPlanes(values.width()),
                values.width() as usize,
                values,
            )?;
        }
        sys.column_sum(alloc, self.pid, values, mask, &mut self.pools)
    }

    /// Trim every session pool to at most `keep` resident buffers —
    /// how a tenant recovers from a scratch-quota rejection.
    pub fn trim(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        keep: usize,
    ) -> Result<()> {
        sys.trim_pools(alloc, self.pid, &mut self.pools, keep)
    }

    /// Drain the session's queue back-to-back as ONE batch (no
    /// cross-tenant interleaving) — the unfair baseline the DRR
    /// scheduler is measured against, and the direct path for
    /// single-tenant use.
    pub fn flush_direct(&mut self, sys: &mut System) -> Result<BatchReport> {
        let reqs: Vec<(Pid, BulkRequest)> =
            self.queue.drain(..).map(|r| (self.pid, r)).collect();
        if reqs.is_empty() {
            return Ok(BatchReport::default());
        }
        let report = sys.submit_batch_tagged(&reqs)?;
        for &ns in &report.per_op_ns {
            sys.coord.obs.registry.observe_ns(self.op_hist, ns);
        }
        Ok(report)
    }

    /// Release every session-held machine resource: pending queue
    /// entries are forfeited, scratch pools returned, cached columns
    /// flushed. The session handle stays reusable afterwards.
    pub fn release(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
    ) -> Result<()> {
        self.queue.clear();
        self.deficit = 0;
        for k in 0..self.pools.n_pools() {
            sys.release_scratch(alloc, self.pid, self.pools.pool(k))?;
        }
        sys.flush_columns(alloc, self.pid)
    }

    /// Scratch-quota admission: compute the projected resident buffer
    /// count across ALL session pools if the kernel behind `key`
    /// leased `extra + scratch_needed` buffers per operand pool, and
    /// refuse (typed, nothing leased) when it exceeds the quota.
    fn admit_kernel(
        &mut self,
        sys: &mut System,
        key: ProgramKey,
        extra: usize,
        a: &Column,
    ) -> Result<()> {
        ensure!(
            a.width() <= arith::MAX_WIDTH,
            "{}-bit operands exceed the {}-bit kernel limit",
            a.width(),
            arith::MAX_WIDTH
        );
        let (prog, _) = sys.program(key);
        let need = extra + prog.scratch_needed();
        let mut projected = 0usize;
        match a {
            Column::Flat(l) => {
                projected += self.pools.pool(0).projected_len(need, l.plane_len());
                for k in 1..self.pools.n_pools() {
                    projected += self.pools.pool(k).len();
                }
            }
            Column::Sharded(s) => {
                for (k, part) in s.shards().iter().enumerate() {
                    projected +=
                        self.pools.pool(k).projected_len(need, part.plane_len());
                }
                for k in s.n_shards()..self.pools.n_pools() {
                    projected += self.pools.pool(k).len();
                }
            }
        }
        if projected > self.scratch_quota {
            return Err(anyhow::Error::new(ServeError::Rejected(
                RejectReason::ScratchExhausted {
                    projected,
                    quota: self.scratch_quota,
                },
            )));
        }
        Ok(())
    }
}

/// Flatten an allocator failure into the typed capacity rejection.
fn capacity(e: anyhow::Error) -> anyhow::Error {
    anyhow::Error::new(ServeError::Rejected(RejectReason::CapacityExhausted {
        detail: e.to_string(),
    }))
}
