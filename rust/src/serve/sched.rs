//! The deficit-round-robin core of the fairness scheduler.
//!
//! Classic DRR (Shreedhar & Varghese): each backlogged tenant queue
//! holds a *deficit counter* in units of DRAM rows. Every round the
//! counter grows by the tenant's credit (`quantum × weight`) and the
//! queue releases requests from its front while the head's row cost
//! fits the remaining deficit; an emptied queue forfeits its deficit
//! (no banking credit while idle). Over time each backlogged tenant
//! drains rows proportionally to its weight regardless of request
//! sizes, and a tenant whose head request is larger than one credit
//! simply accumulates deficit across rounds until it fits — no
//! starvation, no oversized-request privilege.
//!
//! The functions here are pure queue arithmetic so the policy is
//! testable without booting a `System`; `serve::Gateway` owns the
//! per-round loop, tags each released request with its session's
//! `Pid`, and merges the streams round-robin into one
//! `System::submit_batch_tagged` batch per round.

use std::collections::VecDeque;

use crate::pud::isa::BulkRequest;

/// DRR cost of one request: the DRAM rows it touches (minimum 1, so
/// zero-length requests still consume credit and cannot spin the
/// scheduler).
pub fn cost_rows(req: &BulkRequest, row_bytes: u64) -> u64 {
    req.rows(row_bytes).max(1)
}

/// One tenant's share of one DRR round: add `credit` to `deficit`,
/// then release requests from the queue front while the head's cost
/// fits. The deficit resets to zero whenever the queue goes (or
/// already was) empty — idle tenants do not bank credit.
pub fn drain_with_deficit(
    queue: &mut VecDeque<BulkRequest>,
    deficit: &mut u64,
    credit: u64,
    row_bytes: u64,
) -> Vec<BulkRequest> {
    if queue.is_empty() {
        *deficit = 0;
        return Vec::new();
    }
    *deficit = deficit.saturating_add(credit);
    let mut out = Vec::new();
    while let Some(front) = queue.front() {
        let cost = cost_rows(front, row_bytes);
        if cost > *deficit {
            break;
        }
        *deficit -= cost;
        out.push(queue.pop_front().expect("front exists"));
    }
    if queue.is_empty() {
        *deficit = 0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::isa::PudOp;

    const ROW: u64 = 8192;

    fn req(rows: u64) -> BulkRequest {
        BulkRequest::new(PudOp::Zero, 0x1000, vec![], rows * ROW)
    }

    #[test]
    fn fifo_order_is_preserved_within_a_tenant() {
        let mut q: VecDeque<BulkRequest> =
            (1..=4u64).map(|i| req(i)).collect();
        let mut deficit = 0;
        let mut drained = Vec::new();
        while !q.is_empty() {
            drained.extend(drain_with_deficit(&mut q, &mut deficit, 3, ROW));
        }
        let lens: Vec<u64> = drained.iter().map(|r| r.len / ROW).collect();
        assert_eq!(lens, vec![1, 2, 3, 4], "released in submission order");
    }

    #[test]
    fn oversized_head_accumulates_deficit_across_rounds() {
        let mut q: VecDeque<BulkRequest> = [req(5)].into_iter().collect();
        let mut deficit = 0;
        // credit 2/round: rounds 1-2 release nothing, round 3 fits (6 >= 5)
        assert!(drain_with_deficit(&mut q, &mut deficit, 2, ROW).is_empty());
        assert_eq!(deficit, 2);
        assert!(drain_with_deficit(&mut q, &mut deficit, 2, ROW).is_empty());
        assert_eq!(deficit, 4);
        let out = drain_with_deficit(&mut q, &mut deficit, 2, ROW);
        assert_eq!(out.len(), 1);
        assert_eq!(deficit, 0, "queue emptied: leftover credit forfeited");
    }

    #[test]
    fn weights_skew_per_round_row_shares() {
        // two tenants, same backlog, weights 1 vs 3 (credit 2 vs 6)
        let mut q1: VecDeque<BulkRequest> =
            std::iter::repeat_with(|| req(2)).take(12).collect();
        let mut q2 = q1.clone();
        let (mut d1, mut d2) = (0, 0);
        let r1 = drain_with_deficit(&mut q1, &mut d1, 2, ROW);
        let r2 = drain_with_deficit(&mut q2, &mut d2, 6, ROW);
        let rows = |v: &[BulkRequest]| -> u64 {
            v.iter().map(|r| cost_rows(r, ROW)).sum()
        };
        assert_eq!(rows(&r1), 2);
        assert_eq!(rows(&r2), 6, "3x the weight drains 3x the rows");
    }

    #[test]
    fn zero_length_requests_cost_one_row() {
        let zero = BulkRequest::new(PudOp::Zero, 0x1000, vec![], 0);
        assert_eq!(cost_rows(&zero, ROW), 1);
        let mut q: VecDeque<BulkRequest> = [zero].into_iter().collect();
        let mut deficit = 0;
        let out = drain_with_deficit(&mut q, &mut deficit, 1, ROW);
        assert_eq!(out.len(), 1, "zero-length request still drains");
    }

    #[test]
    fn idle_queue_forfeits_deficit() {
        let mut q = VecDeque::new();
        let mut deficit = 7;
        assert!(drain_with_deficit(&mut q, &mut deficit, 4, ROW).is_empty());
        assert_eq!(deficit, 0, "no banking credit while idle");
    }
}
