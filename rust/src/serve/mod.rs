//! Multi-tenant serving front-end (DESIGN.md §15).
//!
//! Everything below this layer is single-tenant: workloads boot a
//! [`System`](crate::coordinator::System), spawn pids, and drive the
//! machine directly. This module is the redesigned public surface for
//! *shared* use of one PUMA machine:
//!
//! * [`session`] — the per-tenant [`Session`] handle. It owns the
//!   tenant's `Pid` (raw pids never cross this boundary), its
//!   submission queue, its scratch pools under a resident-buffer
//!   quota, and its DRR weight. Kernel calls are admission-checked
//!   against the quota *before* anything is leased.
//! * [`sched`] — the deficit-round-robin core: pure queue arithmetic
//!   that converts per-round credit (`quantum × weight`, in DRAM
//!   rows) into a released request prefix, FIFO per tenant.
//! * [`gateway`] — the [`Gateway`] front-end tying both together:
//!   open/close sessions, [`Gateway::submit`] with admission control
//!   and backpressure ([`SubmitOutcome`]), and DRR rounds that merge
//!   tenants' released requests into single multi-pid batches so the
//!   hazard-wave scheduler overlaps them across PUMA's disjoint
//!   subarray timelines.
//! * [`error`] — the typed vocabulary ([`ServeError`],
//!   [`RejectReason`], [`SubmitOutcome`]) the boundary speaks instead
//!   of bare `anyhow` strings.
//!
//! The fairness claim is measurable: `workloads::serve` runs the same
//! tenant mix through DRR rounds and through the back-to-back
//! baseline, asserts byte-identical results, and reports the p99
//! tenant completion time of each (`serve_p99_makespan` in the bench
//! gate).

pub mod error;
pub mod gateway;
pub mod sched;
pub mod session;

pub use error::{RejectReason, ServeError, SubmitOutcome};
pub use gateway::{AdmissionStats, Gateway, GatewayConfig, SessionId};
pub use sched::{cost_rows, drain_with_deficit};
pub use session::{Session, SessionConfig};
