//! The multi-tenant serving front-end.
//!
//! A [`Gateway`] owns the machine ([`System`] + one allocator) and a
//! table of tenant [`Session`]s. Tenants submit [`BulkRequest`]s
//! through [`Gateway::submit`] — admission control classifies each as
//! accepted / backpressured / rejected against the session's queue
//! limits — and the gateway executes them in *DRR rounds*: every
//! round, each backlogged tenant's queue releases up to
//! `quantum × weight` rows' worth of requests ([`sched`]), the
//! released streams are merged round-robin, and the merge runs as ONE
//! `System::submit_batch_tagged` batch, so the hazard-wave scheduler
//! overlaps different tenants' requests across their (PUMA
//! bank-disjoint) subarrays while each tenant's own FIFO order is
//! preserved. Per-tenant completion times are recovered from the
//! batch's per-wave timing (`BatchReport::op_completion_ns`) on a
//! monotonic gateway clock, which is what the serve workload's
//! latency percentiles are computed over.
//!
//! The contrast baseline is [`Gateway::drain_back_to_back`]: one
//! whole-queue batch per tenant, serially — identical results
//! (byte-for-byte; asserted in `tests/prop_serve.rs` and
//! `bench_runtime`), but the p99 tenant completion approaches the
//! *sum* of all tenants' work instead of the slowest single tenant's.

use anyhow::Result;

use crate::alloc::traits::Allocator;
use crate::coordinator::dispatch::BatchReport;
use crate::coordinator::system::{interleave_rounds, System};
use crate::os::process::Pid;
use crate::pud::isa::BulkRequest;

use super::error::{RejectReason, ServeError, SubmitOutcome};
use super::sched::drain_with_deficit;
use super::session::{Session, SessionConfig};

/// Handle one tenant holds on its gateway session. Plain index into
/// the gateway's session table — the tenant never sees a `Pid`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub usize);

/// Gateway construction options.
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// DRR quantum: rows of credit per round per unit of weight.
    pub quantum: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self { quantum: 64 }
    }
}

/// Cumulative admission-control counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Submissions enqueued below the backpressure threshold.
    pub accepted: u64,
    /// Submissions enqueued past it (tenant told to slow down).
    pub queued: u64,
    /// Submissions refused at the hard cap.
    pub rejected: u64,
}

/// The serving front-end (see module docs).
pub struct Gateway {
    /// The machine. Public: reports and benches read stats/metrics
    /// from it directly; tenant-scoped *operations* go through
    /// sessions.
    pub sys: System,
    alloc: Box<dyn Allocator>,
    sessions: Vec<Option<Session>>,
    cfg: GatewayConfig,
    /// Monotonic simulated clock: cumulative elapsed ns of every
    /// batch this gateway executed.
    clock_ns: f64,
    /// DRR rounds executed.
    rounds: u64,
    stats: AdmissionStats,
}

impl Gateway {
    /// Wrap a booted system and its allocator into a gateway.
    pub fn new(
        sys: System,
        alloc: Box<dyn Allocator>,
        cfg: GatewayConfig,
    ) -> Self {
        Self {
            sys,
            alloc,
            sessions: Vec::new(),
            cfg: GatewayConfig { quantum: cfg.quantum.max(1) },
            clock_ns: 0.0,
            rounds: 0,
            stats: AdmissionStats::default(),
        }
    }

    /// Open a tenant session.
    pub fn open(&mut self, cfg: SessionConfig) -> SessionId {
        let sess = Session::open(&mut self.sys, cfg);
        if let Some(i) = self.sessions.iter().position(Option::is_none) {
            self.sessions[i] = Some(sess);
            return SessionId(i);
        }
        self.sessions.push(Some(sess));
        SessionId(self.sessions.len() - 1)
    }

    /// Close a session: releases its scratch pools, cached columns,
    /// and pending queue. The id becomes invalid (and reusable).
    pub fn close(&mut self, id: SessionId) -> Result<()> {
        let mut sess = self
            .sessions
            .get_mut(id.0)
            .and_then(Option::take)
            .ok_or(ServeError::UnknownSession(id.0))?;
        sess.release(&mut self.sys, self.alloc.as_mut())
    }

    /// The session behind `id`.
    pub fn session(&self, id: SessionId) -> Result<&Session> {
        self.sessions
            .get(id.0)
            .and_then(Option::as_ref)
            .ok_or_else(|| ServeError::UnknownSession(id.0).into())
    }

    /// Run `f` against the session behind `id`, with the system and
    /// allocator — the access path for every tenant-scoped operation
    /// (allocation, kernels, reads) on a gateway-owned session.
    pub fn with_session<T>(
        &mut self,
        id: SessionId,
        f: impl FnOnce(&mut Session, &mut System, &mut dyn Allocator) -> Result<T>,
    ) -> Result<T> {
        let sess = self
            .sessions
            .get_mut(id.0)
            .and_then(Option::as_mut)
            .ok_or(ServeError::UnknownSession(id.0))?;
        f(sess, &mut self.sys, self.alloc.as_mut())
    }

    /// Submit one request to `id`'s queue, through admission control.
    /// Rejection is an `Ok(SubmitOutcome::Rejected { .. })`, not an
    /// error — the gateway is healthy, the tenant is over its limits.
    pub fn submit(
        &mut self,
        id: SessionId,
        req: BulkRequest,
    ) -> Result<SubmitOutcome> {
        let sess = self
            .sessions
            .get_mut(id.0)
            .and_then(Option::as_mut)
            .ok_or(ServeError::UnknownSession(id.0))?;
        let depth = sess.queue.len();
        if depth >= sess.queue_cap {
            self.stats.rejected += 1;
            return Ok(SubmitOutcome::Rejected {
                reason: RejectReason::QueueFull { depth, cap: sess.queue_cap },
            });
        }
        sess.queue.push_back(req);
        let depth = depth + 1;
        if depth > sess.backpressure {
            self.stats.queued += 1;
            Ok(SubmitOutcome::Queued { depth })
        } else {
            self.stats.accepted += 1;
            Ok(SubmitOutcome::Accepted { depth })
        }
    }

    /// Requests admitted but not yet executed, across all sessions.
    pub fn pending(&self) -> usize {
        self.sessions
            .iter()
            .flatten()
            .map(|s| s.queue.len())
            .sum()
    }

    /// Admission-control counters.
    pub fn admission_stats(&self) -> AdmissionStats {
        self.stats
    }

    /// The gateway's simulated clock (cumulative batch-elapsed ns).
    pub fn clock_ns(&self) -> f64 {
        self.clock_ns
    }

    /// DRR rounds executed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Execute one DRR round (see module docs). Returns the merged
    /// batch's report, or `None` when no tenant released anything
    /// (idle, or every backlogged head is still accumulating deficit).
    pub fn run_round(&mut self) -> Result<Option<BatchReport>> {
        let row_bytes = self.sys.os.scheme.geometry.row_bytes as u64;
        let quantum = self.cfg.quantum;
        let mut per_tenant: Vec<Vec<(Pid, BulkRequest)>> = Vec::new();
        for sess in self.sessions.iter_mut().flatten() {
            let credit = quantum * sess.weight() as u64;
            let released = drain_with_deficit(
                &mut sess.queue,
                &mut sess.deficit,
                credit,
                row_bytes,
            );
            if !released.is_empty() {
                let pid = sess.pid;
                per_tenant
                    .push(released.into_iter().map(|r| (pid, r)).collect());
            }
        }
        self.rounds += 1;
        if per_tenant.is_empty() {
            return Ok(None);
        }
        let merged = interleave_rounds(per_tenant);
        let report = self.sys.submit_batch_tagged(&merged)?;
        let start = self.clock_ns;
        for (i, (pid, _)) in merged.iter().enumerate() {
            let done = start + report.op_completion_ns(i);
            let ns = report.per_op_ns[i];
            if let Some(sess) = self
                .sessions
                .iter_mut()
                .flatten()
                .find(|s| s.pid == *pid)
            {
                sess.last_done_ns = sess.last_done_ns.max(done);
                self.sys.coord.obs.registry.observe_ns(sess.op_hist, ns);
            }
        }
        self.clock_ns += report.elapsed_ns;
        Ok(Some(report))
    }

    /// Run DRR rounds until every queue drains. Returns the number of
    /// rounds executed. Terminates for any backlog: deficits grow by
    /// `quantum × weight ≥ 1` every round a queue stays backlogged,
    /// so every head request eventually fits.
    pub fn drain(&mut self) -> Result<u64> {
        let mut rounds = 0;
        while self.pending() > 0 {
            self.run_round()?;
            rounds += 1;
        }
        Ok(rounds)
    }

    /// The unfair baseline: drain each session's whole queue as one
    /// back-to-back batch, tenant after tenant in session order — no
    /// interleaving, so tenant `t`'s completion includes every
    /// earlier tenant's full makespan.
    pub fn drain_back_to_back(&mut self) -> Result<()> {
        let Gateway { sys, sessions, clock_ns, .. } = self;
        for sess in sessions.iter_mut().flatten() {
            if sess.queue.is_empty() {
                continue;
            }
            let report = sess.flush_direct(sys)?;
            *clock_ns += report.elapsed_ns;
            sess.last_done_ns = *clock_ns;
        }
        Ok(())
    }

    /// Tenant completion times `(name, completed_ns)` for every live
    /// session, in session order.
    pub fn completions(&self) -> Vec<(String, f64)> {
        self.sessions
            .iter()
            .flatten()
            .map(|s| (s.name().to_string(), s.completed_ns()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::mallocsim::MallocSim;
    use crate::alloc::request::AllocRequest;
    use crate::coordinator::system::SystemConfig;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::pud::isa::PudOp;

    fn small_gateway() -> Gateway {
        let scheme =
            InterleaveScheme::row_major(DramGeometry::small());
        let sys = System::boot(SystemConfig {
            scheme,
            huge_pages: 8,
            churn_rounds: 1_000,
            seed: 11,
            ..Default::default()
        })
        .unwrap();
        Gateway::new(
            sys,
            Box::new(MallocSim::new()),
            GatewayConfig { quantum: 4 },
        )
    }

    #[test]
    fn admission_classifies_accepted_queued_rejected() {
        let mut gw = small_gateway();
        let id = gw.open(SessionConfig {
            backpressure: 2,
            queue_cap: 4,
            ..SessionConfig::named("t0")
        });
        let req = || BulkRequest::new(PudOp::Zero, 0x1000, vec![], 64);
        assert_eq!(
            gw.submit(id, req()).unwrap(),
            SubmitOutcome::Accepted { depth: 1 }
        );
        assert_eq!(
            gw.submit(id, req()).unwrap(),
            SubmitOutcome::Accepted { depth: 2 }
        );
        assert_eq!(
            gw.submit(id, req()).unwrap(),
            SubmitOutcome::Queued { depth: 3 }
        );
        assert_eq!(
            gw.submit(id, req()).unwrap(),
            SubmitOutcome::Queued { depth: 4 }
        );
        assert_eq!(
            gw.submit(id, req()).unwrap(),
            SubmitOutcome::Rejected {
                reason: RejectReason::QueueFull { depth: 4, cap: 4 }
            }
        );
        let st = gw.admission_stats();
        assert_eq!((st.accepted, st.queued, st.rejected), (2, 2, 1));
        assert_eq!(gw.pending(), 4, "rejected request was not enqueued");
    }

    #[test]
    fn unknown_session_is_a_typed_error() {
        let mut gw = small_gateway();
        let req = BulkRequest::new(PudOp::Zero, 0x1000, vec![], 64);
        let err = gw.submit(SessionId(3), req).unwrap_err();
        assert_eq!(
            ServeError::from_anyhow(&err),
            Some(&ServeError::UnknownSession(3))
        );
        let id = gw.open(SessionConfig::default());
        gw.close(id).unwrap();
        assert!(gw.session(id).is_err(), "closed handle is invalid");
    }

    #[test]
    fn drr_drain_executes_everything_and_preserves_results() {
        let mut gw = small_gateway();
        let ids: Vec<SessionId> = (0..3)
            .map(|t| gw.open(SessionConfig::named(format!("t{t}"))))
            .collect();
        let len = 4096u64;
        let mut bufs = Vec::new();
        for &id in &ids {
            let (a, b, c) = gw
                .with_session(id, |sess, sys, alloc| {
                    let a =
                        sess.alloc(sys, alloc, AllocRequest::bytes(len))?;
                    let b =
                        sess.alloc(sys, alloc, AllocRequest::bytes(len))?;
                    let c =
                        sess.alloc(sys, alloc, AllocRequest::bytes(len))?;
                    sess.write(sys, a, &vec![0xF0u8; len as usize])?;
                    sess.write(sys, b, &vec![0x3Cu8; len as usize])?;
                    Ok((a, b, c))
                })
                .unwrap();
            bufs.push((id, a, b, c));
        }
        for &(id, a, b, c) in &bufs {
            gw.submit(id, BulkRequest::new(PudOp::And, c, vec![a, b], len))
                .unwrap();
            gw.submit(id, BulkRequest::new(PudOp::Not, b, vec![c], len))
                .unwrap();
        }
        assert_eq!(gw.pending(), 6);
        gw.drain().unwrap();
        assert_eq!(gw.pending(), 0);
        for &(id, _, b, c) in &bufs {
            let (got_c, got_b) = gw
                .with_session(id, |sess, sys, _| {
                    Ok((sess.read(sys, c, len)?, sess.read(sys, b, len)?))
                })
                .unwrap();
            assert_eq!(got_c, vec![0xF0 & 0x3Cu8; len as usize]);
            assert_eq!(got_b, vec![!(0xF0 & 0x3Cu8); len as usize]);
        }
        // every tenant completed at a positive time on the clock
        for (_, done) in gw.completions() {
            assert!(done > 0.0);
        }
        assert!(gw.clock_ns() > 0.0);
    }
}
