//! Typed errors and submission outcomes at the serving boundary.
//!
//! Everything below the serving tier reports failures as `anyhow`
//! chains — fine for workloads and tests, useless for a front-end that
//! must tell a tenant *why* it was turned away. The gateway therefore
//! speaks two typed vocabularies:
//!
//! * [`SubmitOutcome`] — the non-error admission verdict of every
//!   submission: accepted, accepted-but-backpressured, or rejected
//!   with a [`RejectReason`]. Rejection is not an `Err`: the gateway
//!   itself is healthy, the tenant is over its limits.
//! * [`ServeError`] — genuine serving-boundary failures (unknown
//!   session handles, capacity exhaustion surfacing from the
//!   allocator, scratch-quota overruns on the synchronous kernel
//!   path). Carried inside `anyhow::Error` so the rest of the crate
//!   composes unchanged; callers at the boundary downcast with
//!   [`ServeError::from_anyhow`].

use std::fmt;

/// Why the gateway turned a submission (or a synchronous kernel run)
/// away.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The session's submission queue is at its hard cap.
    QueueFull {
        /// Queue depth at rejection time.
        depth: usize,
        /// The session's configured hard cap.
        cap: usize,
    },
    /// Granting the lease would push the session's resident scratch
    /// past its quota (see `ScratchPool::projected_len`).
    ScratchExhausted {
        /// Projected resident buffers across the session's pools.
        projected: usize,
        /// The session's configured quota.
        quota: usize,
    },
    /// The backing allocator (typically the PUMA subarray pool) could
    /// not place the request.
    CapacityExhausted {
        /// The underlying allocator error, flattened to text.
        detail: String,
    },
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull { depth, cap } => {
                write!(f, "queue full: {depth} request(s) at cap {cap}")
            }
            RejectReason::ScratchExhausted { projected, quota } => write!(
                f,
                "scratch quota exhausted: {projected} projected resident \
                 buffer(s) over quota {quota}"
            ),
            RejectReason::CapacityExhausted { detail } => {
                write!(f, "capacity exhausted: {detail}")
            }
        }
    }
}

/// A typed serving-boundary failure (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The session handle does not name a live session.
    UnknownSession(usize),
    /// A synchronous operation was refused by admission control.
    Rejected(RejectReason),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownSession(id) => {
                write!(f, "unknown session {id}")
            }
            ServeError::Rejected(r) => write!(f, "rejected: {r}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// The typed serving error inside an `anyhow` chain, if any.
    pub fn from_anyhow(err: &anyhow::Error) -> Option<&ServeError> {
        err.downcast_ref::<ServeError>()
    }
}

/// Admission verdict of one [`Gateway::submit`](super::Gateway::submit).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Enqueued below the backpressure threshold.
    Accepted {
        /// Queue depth after the enqueue.
        depth: usize,
    },
    /// Enqueued past the backpressure threshold but under the hard
    /// cap — the tenant should slow down.
    Queued {
        /// Queue depth after the enqueue.
        depth: usize,
    },
    /// Not enqueued.
    Rejected {
        /// Why admission control refused it.
        reason: RejectReason,
    },
}

impl SubmitOutcome {
    /// True when the request was enqueued (accepted or backpressured).
    pub fn is_admitted(&self) -> bool {
        !matches!(self, SubmitOutcome::Rejected { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_error_round_trips_through_anyhow() {
        let err = anyhow::Error::new(ServeError::Rejected(
            RejectReason::ScratchExhausted { projected: 9, quota: 4 },
        ));
        let back = ServeError::from_anyhow(&err).unwrap();
        assert_eq!(
            back,
            &ServeError::Rejected(RejectReason::ScratchExhausted {
                projected: 9,
                quota: 4
            })
        );
        assert!(err.to_string().contains("quota 4"));
        let plain = anyhow::anyhow!("some other failure");
        assert!(ServeError::from_anyhow(&plain).is_none());
    }

    #[test]
    fn outcomes_classify_admission() {
        assert!(SubmitOutcome::Accepted { depth: 1 }.is_admitted());
        assert!(SubmitOutcome::Queued { depth: 5 }.is_admitted());
        assert!(!SubmitOutcome::Rejected {
            reason: RejectReason::QueueFull { depth: 8, cap: 8 }
        }
        .is_admitted());
    }
}
