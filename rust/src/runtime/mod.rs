//! XLA/PJRT runtime for the CPU-fallback path.
//!
//! * [`manifest`] — build-time contract: parses `artifacts/manifest.tsv`.
//! * [`client`] — [`client::XlaRuntime`]: PJRT CPU client, per-(op,
//!   bucket) executable cache, greedy shape bucketing, byte-level I/O.
//!
//! The runtime is optional at the API level (simulation-only runs use
//! the scalar fallback in [`crate::pud::exec`]); the end-to-end driver
//! and the benchmarks load it so the full three-layer stack executes.
//! Without the `xla-runtime` cargo feature the client compiles against
//! [`pjrt_stub`], which fails cleanly at client construction — the
//! offline vendor set has no PJRT bindings (DESIGN.md §7).

pub mod client;
pub mod manifest;
#[cfg(not(feature = "xla-runtime"))]
pub mod pjrt_stub;

pub use client::{XlaRuntime, LANES, ROW_BYTES};
