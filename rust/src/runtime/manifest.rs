//! Artifact manifest parsing.
//!
//! `python -m compile.aot` writes `artifacts/manifest.tsv` describing
//! every lowered HLO module (name, op, rows bucket, lanes, arity,
//! dtype, file). The manifest is the build-time contract between L2
//! and this runtime: the executable cache loads exactly what it lists.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub op: String,
    pub rows: u32,
    pub lanes: u32,
    pub arity: usize,
    pub dtype: String,
    pub path: PathBuf,
}

/// Parse `manifest.tsv` in `dir`; paths are resolved relative to it.
pub fn load(dir: impl AsRef<Path>) -> Result<Vec<ManifestEntry>> {
    let dir = dir.as_ref();
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
    parse(&text, dir)
}

fn parse(text: &str, dir: &Path) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 7 {
            bail!(
                "manifest line {} has {} columns, want 7: {line:?}",
                lineno + 1,
                cols.len()
            );
        }
        entries.push(ManifestEntry {
            name: cols[0].to_string(),
            op: cols[1].to_string(),
            rows: cols[2].parse().context("rows column")?,
            lanes: cols[3].parse().context("lanes column")?,
            arity: cols[4].parse().context("arity column")?,
            dtype: cols[5].to_string(),
            path: dir.join(cols[6]),
        });
    }
    if entries.is_empty() {
        bail!("manifest is empty");
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# name\top\trows\tlanes\tarity\tdtype\tfile
and_r1\tand\t1\t2048\t2\ti32\tand_r1.hlo.txt
zero_r64\tzero\t64\t2048\t0\ti32\tzero_r64.hlo.txt
";

    #[test]
    fn parses_sample() {
        let es = parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].name, "and_r1");
        assert_eq!(es[0].arity, 2);
        assert_eq!(es[1].rows, 64);
        assert_eq!(es[1].path, Path::new("/tmp/a/zero_r64.hlo.txt"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("bad line\n", Path::new(".")).is_err());
        assert!(parse("", Path::new(".")).is_err());
        assert!(parse("# only comments\n", Path::new(".")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // integration smoke: if the build produced artifacts, the
        // manifest must parse and include every PudOp kernel.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return; // artifacts not built in this environment
        }
        let es = load(&dir).unwrap();
        for op in ["and", "or", "xor", "not", "copy", "zero"] {
            assert!(
                es.iter().any(|e| e.op == op),
                "missing artifacts for op {op}"
            );
        }
        for e in &es {
            assert!(e.path.exists(), "missing file {}", e.path.display());
        }
    }
}
