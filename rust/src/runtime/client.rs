//! XLA/PJRT CPU runtime: loads the AOT HLO artifacts and executes the
//! CPU-fallback bulk operations.
//!
//! This is the only place the request path touches compiled L1/L2
//! code; python is never invoked at runtime. HLO *text* is the
//! interchange format (jax >= 0.5 protos are rejected by the image's
//! xla_extension 0.5.1 — see DESIGN.md §7 and aot.py).
//!
//! Shape bucketing: every op is compiled at the row buckets lowered by
//! aot.py ({1, 8, 64, 256} x 2048 i32 lanes). [`XlaRuntime::run_op`]
//! greedily covers an arbitrary row count with the largest buckets, so
//! dispatch count is O(log rows + rows/256).

use anyhow::{anyhow, bail, Context, Result};
use rustc_hash::FxHashMap;

use super::manifest::{self, ManifestEntry};

// Without the feature, `xla::` resolves to the inert stub; with it,
// the real bindings must be supplied externally (DESIGN.md §7).
#[cfg(not(feature = "xla-runtime"))]
use super::pjrt_stub as xla;

/// Bytes per DRAM row as seen by the kernels (2048 x i32).
pub const ROW_BYTES: usize = 8192;
pub const LANES: usize = 2048;

/// One compiled executable plus its metadata.
struct CachedExe {
    exe: xla::PjRtLoadedExecutable,
    rows: u32,
    arity: usize,
}

/// The PJRT CPU runtime with a per-(op, bucket) executable cache.
pub struct XlaRuntime {
    _client: xla::PjRtClient,
    /// op -> bucket row counts, descending.
    buckets: FxHashMap<String, Vec<u32>>,
    exes: FxHashMap<(String, u32), CachedExe>,
    /// executions performed, per op (for reports).
    pub dispatches: u64,
}

impl XlaRuntime {
    /// Load every artifact in `dir` and compile it on the CPU client.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let entries = manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        let mut rt = Self {
            _client: client,
            buckets: FxHashMap::default(),
            exes: FxHashMap::default(),
            dispatches: 0,
        };
        for e in &entries {
            rt.compile_entry(e)
                .with_context(|| format!("compiling artifact {}", e.name))?;
        }
        for b in rt.buckets.values_mut() {
            b.sort_unstable_by(|a, b| b.cmp(a));
        }
        Ok(rt)
    }

    fn compile_entry(&mut self, e: &ManifestEntry) -> Result<()> {
        if e.lanes as usize != LANES {
            bail!("artifact {} has {} lanes, runtime expects {LANES}", e.name, e.lanes);
        }
        let proto = xla::HloModuleProto::from_text_file(
            e.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(to_anyhow)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self._client.compile(&comp).map_err(to_anyhow)?;
        self.buckets.entry(e.op.clone()).or_default().push(e.rows);
        self.exes.insert(
            (e.op.clone(), e.rows),
            CachedExe {
                exe,
                rows: e.rows,
                arity: e.arity,
            },
        );
        Ok(())
    }

    /// Ops available in the cache.
    pub fn ops(&self) -> Vec<String> {
        let mut v: Vec<String> = self.buckets.keys().cloned().collect();
        v.sort();
        v
    }

    /// Greedy bucket cover for `rows`: largest bucket <= remaining,
    /// or the smallest bucket for the tail.
    pub fn plan_buckets(&self, op: &str, rows: u32) -> Result<Vec<u32>> {
        let buckets = self
            .buckets
            .get(op)
            .ok_or_else(|| anyhow!("no artifacts for op {op:?}"))?;
        let smallest = *buckets.last().expect("nonempty");
        let mut plan = Vec::new();
        let mut left = rows;
        while left > 0 {
            let b = buckets.iter().copied().find(|&b| b <= left).unwrap_or(smallest);
            plan.push(b);
            left = left.saturating_sub(b);
        }
        Ok(plan)
    }

    /// Execute `op` over whole rows: `srcs` are `arity` byte slices of
    /// `rows * ROW_BYTES` bytes; returns the destination bytes.
    ///
    /// The tail of a partial final row (if `byte_len < rows*ROW_BYTES`)
    /// is the caller's concern: pass padded inputs and truncate the
    /// output.
    pub fn run_op(&mut self, op: &str, rows: u32, srcs: &[&[u8]]) -> Result<Vec<u8>> {
        let total = rows as usize * ROW_BYTES;
        for (i, s) in srcs.iter().enumerate() {
            if s.len() != total {
                bail!("src {i} has {} bytes, want {total}", s.len());
            }
        }
        let plan = self.plan_buckets(op, rows)?;
        // output accumulates as i32 (the artifact element type) so
        // result literals can copy_raw_to straight into the tail —
        // one copy instead of to_vec + extend (§Perf)
        let mut out_i32: Vec<i32> = Vec::with_capacity(total / 4);
        let mut row_off = 0usize;
        for bucket in plan {
            let chunk_bytes = bucket as usize * ROW_BYTES;
            let start = row_off * ROW_BYTES;
            // the greedy tail may overhang; clamp inputs by padding
            let exe = self
                .exes
                .get(&(op.to_string(), bucket))
                .ok_or_else(|| anyhow!("missing exe {op}@{bucket}"))?;
            if exe.arity != srcs.len() {
                bail!("op {op} arity {} but {} srcs given", exe.arity, srcs.len());
            }
            let mut lits = Vec::with_capacity(srcs.len());
            for s in srcs {
                let end = (start + chunk_bytes).min(s.len());
                // exact-fit chunks (the common case) go straight from
                // the caller's slice; only the greedy tail's overhang
                // needs a padded copy (§Perf: saves one memcpy of up
                // to 2 MiB per operand per dispatch)
                let lit = if end - start == chunk_bytes {
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &[exe.rows as usize, LANES],
                        &s[start..end],
                    )
                } else {
                    let mut bytes = s[start..end].to_vec();
                    bytes.resize(chunk_bytes, 0); // pad overhang
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &[exe.rows as usize, LANES],
                        &bytes,
                    )
                };
                lits.push(lit.map_err(to_anyhow)?);
            }
            let result = exe.exe.execute::<xla::Literal>(&lits).map_err(to_anyhow)?;
            let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
            let tuple = lit.to_tuple1().map_err(to_anyhow)?;
            let chunk_elems = chunk_bytes / 4;
            let keep = chunk_bytes.min(total - out_i32.len() * 4) / 4;
            let pos = out_i32.len();
            if keep == chunk_elems {
                // exact fit: copy the literal straight into the tail
                out_i32.resize(pos + chunk_elems, 0);
                tuple
                    .copy_raw_to(&mut out_i32[pos..pos + chunk_elems])
                    .map_err(to_anyhow)?;
            } else {
                // greedy-tail overhang: stage and truncate
                let vals: Vec<i32> = tuple.to_vec().map_err(to_anyhow)?;
                out_i32.extend_from_slice(&vals[..keep]);
            }
            self.dispatches += 1;
            row_off += bucket as usize;
        }
        debug_assert_eq!(out_i32.len() * 4, total);
        // reinterpret Vec<i32> as Vec<u8> without copying (alignment
        // of u8 <= i32; length/capacity scale by 4)
        let out = unsafe {
            let mut v = std::mem::ManuallyDrop::new(out_i32);
            Vec::from_raw_parts(v.as_mut_ptr() as *mut u8, v.len() * 4, v.capacity() * 4)
        };
        Ok(out)
    }

    /// Execute the fused bitmap-scan artifact: popcount(a & b) summed
    /// over `rows` full rows (used by examples/database_scan).
    pub fn bitmap_scan(&mut self, rows: u32, a: &[u8], b: &[u8]) -> Result<i64> {
        let plan = self.plan_buckets("bitmapscan", rows)?;
        let total = rows as usize * ROW_BYTES;
        if a.len() != total || b.len() != total {
            bail!("bitmap_scan operand size mismatch");
        }
        let mut sum = 0i64;
        let mut row_off = 0usize;
        for bucket in plan {
            let chunk = bucket as usize * ROW_BYTES;
            let start = row_off * ROW_BYTES;
            let exe = self
                .exes
                .get(&("bitmapscan".to_string(), bucket))
                .ok_or_else(|| anyhow!("missing bitmapscan@{bucket}"))?;
            let mut lits = Vec::with_capacity(2);
            for s in [a, b] {
                let end = (start + chunk).min(s.len());
                let mut bytes = s[start..end].to_vec();
                bytes.resize(chunk, 0);
                lits.push(
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &[exe.rows as usize, LANES],
                        &bytes,
                    )
                    .map_err(to_anyhow)?,
                );
            }
            let result = exe.exe.execute::<xla::Literal>(&lits).map_err(to_anyhow)?;
            let lit = result[0][0].to_literal_sync().map_err(to_anyhow)?;
            let vals: Vec<i32> = lit.to_tuple1().map_err(to_anyhow)?.to_vec().map_err(to_anyhow)?;
            sum += vals[0] as i64;
            self.dispatches += 1;
            row_off += bucket as usize;
        }
        Ok(sum)
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.tsv").exists().then_some(dir)
    }

    #[test]
    fn bucket_planning_greedy() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = XlaRuntime::load(dir).unwrap();
        assert_eq!(rt.plan_buckets("and", 1).unwrap(), vec![1]);
        assert_eq!(rt.plan_buckets("and", 8).unwrap(), vec![8]);
        assert_eq!(rt.plan_buckets("and", 9).unwrap(), vec![8, 1]);
        assert_eq!(
            rt.plan_buckets("and", 300).unwrap(),
            vec![256, 8, 8, 8, 8, 8, 1, 1, 1, 1]
        );
        assert!(rt.plan_buckets("nonesuch", 1).is_err());
    }

    #[test]
    fn and_matches_scalar_reference() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = XlaRuntime::load(dir).unwrap();
        let mut rng = Pcg64::new(21);
        let rows = 3u32;
        let n = rows as usize * ROW_BYTES;
        let mut a = vec![0u8; n];
        let mut b = vec![0u8; n];
        rng.fill_bytes(&mut a);
        rng.fill_bytes(&mut b);
        let got = rt.run_op("and", rows, &[&a, &b]).unwrap();
        let want: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x & y).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn zero_and_copy_and_not() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = XlaRuntime::load(dir).unwrap();
        let mut rng = Pcg64::new(22);
        let n = ROW_BYTES;
        let mut a = vec![0u8; n];
        rng.fill_bytes(&mut a);
        assert_eq!(rt.run_op("zero", 1, &[]).unwrap(), vec![0u8; n]);
        assert_eq!(rt.run_op("copy", 1, &[&a]).unwrap(), a);
        let not: Vec<u8> = a.iter().map(|x| !x).collect();
        assert_eq!(rt.run_op("not", 1, &[&a]).unwrap(), not);
    }

    #[test]
    fn bitmap_scan_counts_bits() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = XlaRuntime::load(dir).unwrap();
        let n = 2 * ROW_BYTES;
        let a = vec![0xFFu8; n];
        let mut b = vec![0u8; n];
        b[0] = 0b1011;
        b[ROW_BYTES] = 0xFF;
        let got = rt.bitmap_scan(2, &a, &b).unwrap();
        assert_eq!(got, 3 + 8);
    }

    #[test]
    fn run_op_validates_sizes() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = XlaRuntime::load(dir).unwrap();
        let a = vec![0u8; 100];
        assert!(rt.run_op("and", 1, &[&a, &a]).is_err());
    }
}
