//! Inert stand-in for the `xla` (PJRT) bindings.
//!
//! The offline build environment does not ship the XLA/PJRT Rust
//! bindings, so [`client`](super::client) compiles against this module
//! unless the `xla-runtime` cargo feature is enabled (DESIGN.md §7).
//! The stub mirrors exactly the API surface the client uses; creating
//! the CPU client fails with a descriptive error, so every downstream
//! path (e.g. `System::boot` with an artifacts dir) degrades into a
//! clean error while simulation-only runs — which use the scalar
//! fallback and never construct a client — are unaffected.
#![allow(dead_code)]

use std::fmt;

/// Error type mirroring `xla::Error` (only `Display` is consumed).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "XLA/PJRT bindings unavailable: built without the `xla-runtime` \
         feature (see DESIGN.md §7)"
            .to_string(),
    ))
}

/// Element types the client requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S32,
}

/// Mirrors `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable()
    }
}

/// Mirrors `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Mirrors `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self, Error> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<(), Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Mirrors `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Mirrors `xla::PjRtClient`. The CPU constructor is the single entry
/// point, so failing here keeps every later stub method unreachable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_missing_feature() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(err.to_string().contains("xla-runtime"));
    }
}
