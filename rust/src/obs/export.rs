//! Exporters for the tracer ring and the metrics registry.
//!
//! Three formats (DESIGN.md §14):
//!
//! - **Chrome trace-event JSON** ([`chrome_trace`]) — loadable in
//!   Perfetto / `chrome://tracing`. Each dense bank id is a lane
//!   (`tid`) under the "PUD banks" process; each wave contributes one
//!   duration event per active lane, plus a "host fallback" lane for
//!   the wave's serialized CPU leg. Timestamps are sim-time µs.
//! - **DDR-style command stream** ([`ddr_stream`]) — a flat text
//!   record per wave/op with ACT/AAP/TRA counts expanded from the
//!   `PudOp` cost table and `HOST` records for fallback legs
//!   (ROADMAP item 3, PiDRAM-style). Floats are serialized with `{:?}`
//!   so they round-trip bit-exactly; [`replay_ddr`] re-absorbs the
//!   stream in submission order and reproduces the coordinator-work
//!   subset of [`CoordStats`] *byte-identically* (verified by
//!   [`verify_replay`]).
//! - **Prometheus text dump** ([`prometheus`]) — counters, gauges, and
//!   histogram summaries (p50/p90/p99) of a registry snapshot.

use anyhow::{bail, Context, Result};

use crate::coordinator::CoordStats;
use crate::pud::isa::PudOp;
use crate::util::stats::HitRate;

use super::metrics::Snapshot;
use super::trace::WaveEvent;

/// Serialize an f64 so it parses back bit-exactly (`{:?}` emits the
/// shortest representation that round-trips).
fn f(v: f64) -> String {
    format!("{v:?}")
}

// ---------------------------------------------------------------------
// Chrome trace-event / Perfetto JSON
// ---------------------------------------------------------------------

const PID_BANKS: u32 = 1;
const PID_HOST: u32 = 2;

fn push_event(out: &mut String, first: &mut bool, body: &str) {
    if !*first {
        out.push(',');
    }
    *first = false;
    out.push('\n');
    out.push_str("    ");
    out.push_str(body);
}

/// Render `events` as Chrome trace-event JSON (µs timestamps).
pub fn chrome_trace(events: &[WaveEvent]) -> String {
    let mut out = String::from("{\n  \"displayTimeUnit\": \"ns\",\n  \"traceEvents\": [");
    let mut first = true;
    push_event(
        &mut out,
        &mut first,
        &format!(
            "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{PID_BANKS},\"tid\":0,\
             \"args\":{{\"name\":\"PUD banks (sim)\"}}}}"
        ),
    );
    push_event(
        &mut out,
        &mut first,
        &format!(
            "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{PID_HOST},\"tid\":0,\
             \"args\":{{\"name\":\"host fallback (sim)\"}}}}"
        ),
    );
    push_event(
        &mut out,
        &mut first,
        &format!(
            "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{PID_HOST},\"tid\":0,\
             \"args\":{{\"name\":\"cpu\"}}}}"
        ),
    );
    let mut named_lanes: Vec<u32> = Vec::new();
    for ev in events {
        for lane in &ev.lanes {
            if !named_lanes.contains(&lane.bank) {
                named_lanes.push(lane.bank);
                push_event(
                    &mut out,
                    &mut first,
                    &format!(
                        "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{PID_BANKS},\
                         \"tid\":{},\"args\":{{\"name\":\"bank {}\"}}}}",
                        lane.bank, lane.bank
                    ),
                );
            }
            push_event(
                &mut out,
                &mut first,
                &format!(
                    "{{\"ph\":\"X\",\"name\":\"wave {}\",\"pid\":{PID_BANKS},\"tid\":{},\
                     \"ts\":{},\"dur\":{},\"args\":{{\"batch\":{},\"rows\":{}}}}}",
                    ev.wave,
                    lane.bank,
                    f(ev.start_ns / 1000.0),
                    f(lane.busy_ns / 1000.0),
                    ev.batch,
                    lane.rows
                ),
            );
        }
        if ev.fallback_ns > 0.0 {
            let fb_rows: u64 = ev.ops.iter().map(|o| o.fallback_rows).sum();
            push_event(
                &mut out,
                &mut first,
                &format!(
                    "{{\"ph\":\"X\",\"name\":\"wave {} fallback\",\"pid\":{PID_HOST},\
                     \"tid\":0,\"ts\":{},\"dur\":{},\"args\":{{\"batch\":{},\"rows\":{}}}}}",
                    ev.wave,
                    f((ev.start_ns + ev.pud_ns) / 1000.0),
                    f(ev.fallback_ns / 1000.0),
                    ev.batch,
                    fb_rows
                ),
            );
        }
    }
    out.push_str("\n  ]\n}\n");
    out
}

// ---------------------------------------------------------------------
// DDR-style command stream + replay
// ---------------------------------------------------------------------

/// Render `events` as a flat DDR-style command stream. Command counts
/// are aggregated per op slot (`n=` repeat counts) so the stream stays
/// O(ops), not O(rows x AAPs); each `AAP` is two back-to-back `ACT`s,
/// which is why `ACT n` is always twice `AAP n`.
pub fn ddr_stream(events: &[WaveEvent]) -> String {
    let mut out = String::from("# puma-ddr-stream v1\n");
    for ev in events {
        out.push_str(&format!(
            "WAVE {} batch={} start_ns={} pud_ns={} fallback_ns={}\n",
            ev.wave,
            ev.batch,
            f(ev.start_ns),
            f(ev.pud_ns),
            f(ev.fallback_ns)
        ));
        for slot in &ev.ops {
            out.push_str(&format!(
                "OP {} pud_rows={} fb_rows={} pud_bytes={} fb_bytes={} pud_ns={} fb_ns={}\n",
                slot.op.kernel_name(),
                slot.pud_rows,
                slot.fallback_rows,
                slot.pud_bytes,
                slot.fallback_bytes,
                f(slot.pud_ns),
                f(slot.fallback_ns)
            ));
            let aaps = slot.op.aaps_per_row() * slot.pud_rows;
            let tras = slot.op.tras_per_row() * slot.pud_rows;
            if slot.pud_rows > 0 {
                out.push_str(&format!("ACT n={} t={}\n", 2 * aaps, f(ev.start_ns)));
                out.push_str(&format!("AAP n={} t={}\n", aaps, f(ev.start_ns)));
                if tras > 0 {
                    out.push_str(&format!("TRA n={} t={}\n", tras, f(ev.start_ns)));
                }
            }
            if slot.fallback_rows > 0 {
                out.push_str(&format!(
                    "HOST rows={} bytes={} t={}\n",
                    slot.fallback_rows,
                    slot.fallback_bytes,
                    f(ev.start_ns + ev.pud_ns)
                ));
            }
        }
    }
    out
}

fn field<'a>(tokens: &[&'a str], key: &str) -> Result<&'a str> {
    tokens
        .iter()
        .find_map(|t| t.strip_prefix(key).and_then(|r| r.strip_prefix('=')))
        .with_context(|| format!("missing field {key}"))
}

fn op_by_kernel(name: &str) -> Result<PudOp> {
    PudOp::ALL
        .into_iter()
        .find(|o| o.kernel_name() == name)
        .with_context(|| format!("unknown op kernel {name:?}"))
}

/// Replay a [`ddr_stream`] back into the coordinator-work subset of
/// [`CoordStats`]. Accumulation happens in stream order with the
/// bit-exact parsed floats, so the result is byte-identical to the
/// live stats (see [`coordinator_work`]). The AAP/TRA repeat counts
/// are cross-checked against the `PudOp` cost table while replaying.
pub fn replay_ddr(stream: &str) -> Result<CoordStats> {
    let mut stats = CoordStats::default();
    let mut line_no = 0usize;
    let mut cur_op: Option<(PudOp, u64)> = None;
    for line in stream.lines() {
        line_no += 1;
        let parse = |what: &str, v: &str| -> Result<u64> {
            v.parse::<u64>()
                .with_context(|| format!("line {line_no}: bad {what} {v:?}"))
        };
        let tokens: Vec<&str> = line.split_whitespace().collect();
        match tokens.first().copied() {
            Some("OP") => {
                let op = op_by_kernel(tokens.get(1).copied().unwrap_or(""))
                    .with_context(|| format!("line {line_no}"))?;
                let pud_rows = parse("pud_rows", field(&tokens, "pud_rows")?)?;
                let fb_rows = parse("fb_rows", field(&tokens, "fb_rows")?)?;
                let pud_ns: f64 = field(&tokens, "pud_ns")?
                    .parse()
                    .with_context(|| format!("line {line_no}: bad pud_ns"))?;
                let fb_ns: f64 = field(&tokens, "fb_ns")?
                    .parse()
                    .with_context(|| format!("line {line_no}: bad fb_ns"))?;
                stats.ops += 1;
                stats.ops_fully_pud.record(fb_rows == 0 && pud_rows > 0);
                stats.pud_rows += pud_rows;
                stats.fallback_rows += fb_rows;
                stats.pud_bytes += parse("pud_bytes", field(&tokens, "pud_bytes")?)?;
                stats.fallback_bytes += parse("fb_bytes", field(&tokens, "fb_bytes")?)?;
                stats.pud_ns += pud_ns;
                stats.fallback_ns += fb_ns;
                cur_op = Some((op, pud_rows));
            }
            Some("AAP") => {
                let (op, rows) =
                    cur_op.with_context(|| format!("line {line_no}: AAP before OP"))?;
                let n = parse("n", field(&tokens, "n")?)?;
                let want = op.aaps_per_row() * rows;
                if n != want {
                    bail!("line {line_no}: AAP count {n} != {want} for {op:?} x{rows}");
                }
            }
            Some("TRA") => {
                let (op, rows) =
                    cur_op.with_context(|| format!("line {line_no}: TRA before OP"))?;
                let n = parse("n", field(&tokens, "n")?)?;
                let want = op.tras_per_row() * rows;
                if n != want {
                    bail!("line {line_no}: TRA count {n} != {want} for {op:?} x{rows}");
                }
            }
            Some("ACT") => {
                let (op, rows) =
                    cur_op.with_context(|| format!("line {line_no}: ACT before OP"))?;
                let n = parse("n", field(&tokens, "n")?)?;
                let want = 2 * op.aaps_per_row() * rows;
                if n != want {
                    bail!("line {line_no}: ACT count {n} != {want} for {op:?} x{rows}");
                }
            }
            Some("WAVE") | Some("HOST") | Some("#") | None => {}
            Some(other) => bail!("line {line_no}: unknown record {other:?}"),
        }
    }
    Ok(stats)
}

/// The coordinator-work subset of `stats`: what the executor absorbed
/// from `ExecStats`, with the allocation-side and dispatch-shape
/// counters (`alloc_ns`, `xla_*`) zeroed — those never enter the
/// command stream.
pub fn coordinator_work(stats: &CoordStats) -> CoordStats {
    CoordStats {
        alloc_ns: 0.0,
        xla_dispatches: 0,
        xla_wall_ns: 0,
        ..stats.clone()
    }
}

/// Assert that replaying `stream` reproduces `stats` byte-identically
/// (coordinator-work subset). Requires a complete capture: the tracer
/// must have been enabled since the coordinator's stats were last
/// zero, with no dropped events.
pub fn verify_replay(stream: &str, stats: &CoordStats) -> Result<()> {
    let replayed = replay_ddr(stream)?;
    let want = coordinator_work(stats);
    if replayed != want {
        bail!(
            "DDR replay does not reproduce CoordStats\n  replayed: {replayed:?}\n  \
             expected: {want:?}"
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Prometheus-style text dump
// ---------------------------------------------------------------------

fn prom_name(name: &str) -> String {
    let mut out = String::from("puma_");
    for c in name.chars() {
        out.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    out
}

/// Render a registry snapshot in the Prometheus text exposition
/// format (histograms as summaries with p50/p90/p99 quantiles).
pub fn prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", f(*v)));
    }
    for (name, h) in &snap.hists {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} summary\n"));
        for (q, p) in [(0.5, h.p50()), (0.9, h.p90()), (0.99, h.p99())] {
            out.push_str(&format!("{n}{{quantile=\"{q}\"}} {p}\n"));
        }
        out.push_str(&format!("{n}_sum {}\n{n}_count {}\n", h.sum, h.count));
    }
    out
}

/// Convenience used by `puma trace --export <dir>`: write the Chrome
/// trace, the DDR stream, and the Prometheus dump into `dir` and
/// verify the stream's replay against `stats`.
pub fn export_dir(
    dir: &std::path::Path,
    events: &[WaveEvent],
    snap: &Snapshot,
    stats: &CoordStats,
) -> Result<(std::path::PathBuf, std::path::PathBuf, std::path::PathBuf)> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating export dir {}", dir.display()))?;
    let trace_path = dir.join("trace.json");
    let ddr_path = dir.join("ddr_stream.txt");
    let prom_path = dir.join("metrics.prom");
    let stream = ddr_stream(events);
    verify_replay(&stream, stats)?;
    std::fs::write(&trace_path, chrome_trace(events))?;
    std::fs::write(&ddr_path, stream)?;
    std::fs::write(&prom_path, prometheus(snap))?;
    Ok((trace_path, ddr_path, prom_path))
}

/// Rebuild the `ops_fully_pud` hit-rate a stream implies — exposed for
/// tests that want to diff against a live [`HitRate`] directly.
pub fn replayed_hit_rate(stream: &str) -> Result<HitRate> {
    Ok(replay_ddr(stream)?.ops_fully_pud)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{BankLane, OpSlot, Tracer, WaveEvent};

    fn slot(op: PudOp, pud_rows: u64, fb_rows: u64) -> OpSlot {
        OpSlot {
            op,
            pud_rows,
            fallback_rows: fb_rows,
            pud_bytes: pud_rows * 8192,
            fallback_bytes: fb_rows * 8192,
            pud_ns: pud_rows as f64 * 360.0 + 0.1,
            fallback_ns: fb_rows as f64 * 1365.333333,
        }
    }

    fn sample_events() -> Vec<WaveEvent> {
        let mut t = Tracer::new(16);
        t.record(WaveEvent {
            batch: 0,
            wave: 0,
            start_ns: 0.0,
            pud_ns: 920.0,
            fallback_ns: 1365.3,
            lanes: vec![
                BankLane {
                    bank: 0,
                    rows: 2,
                    busy_ns: 720.0,
                },
                BankLane {
                    bank: 5,
                    rows: 1,
                    busy_ns: 360.0,
                },
            ],
            ops: vec![slot(PudOp::And, 2, 0), slot(PudOp::Copy, 1, 1)],
        });
        t.record(WaveEvent {
            batch: 1,
            wave: 0,
            start_ns: 0.0,
            pud_ns: 830.0,
            fallback_ns: 0.0,
            lanes: vec![BankLane {
                bank: 5,
                rows: 1,
                busy_ns: 630.0,
            }],
            ops: vec![slot(PudOp::Xor, 1, 0)],
        });
        t.events().to_vec()
    }

    fn stats_of(events: &[WaveEvent]) -> CoordStats {
        // absorb in submission order, exactly like the executor
        let mut s = CoordStats::default();
        for ev in events {
            for o in &ev.ops {
                s.ops += 1;
                s.ops_fully_pud.record(o.fallback_rows == 0 && o.pud_rows > 0);
                s.pud_rows += o.pud_rows;
                s.fallback_rows += o.fallback_rows;
                s.pud_bytes += o.pud_bytes;
                s.fallback_bytes += o.fallback_bytes;
                s.pud_ns += o.pud_ns;
                s.fallback_ns += o.fallback_ns;
            }
        }
        s
    }

    #[test]
    fn ddr_replay_is_byte_identical() {
        let events = sample_events();
        let stream = ddr_stream(&events);
        let stats = stats_of(&events);
        verify_replay(&stream, &stats).unwrap();
        // and the replay notices tampering
        let tampered = stream.replace("pud_rows=2", "pud_rows=3");
        assert!(verify_replay(&tampered, &stats).is_err());
    }

    #[test]
    fn ddr_replay_checks_command_counts() {
        let events = sample_events();
        let stream = ddr_stream(&events);
        // And = 4 AAPs/row, 2 rows -> AAP n=8; corrupt it
        let bad = stream.replace("AAP n=8", "AAP n=7");
        assert_ne!(bad, stream, "expected an AAP n=8 record to corrupt");
        assert!(replay_ddr(&bad).is_err());
    }

    #[test]
    fn ddr_replay_ignores_alloc_and_xla_counters() {
        let events = sample_events();
        let stream = ddr_stream(&events);
        let mut stats = stats_of(&events);
        stats.alloc_ns = 1234.5;
        stats.xla_dispatches = 9;
        stats.xla_wall_ns = 777;
        verify_replay(&stream, &stats).unwrap();
    }

    #[test]
    fn chrome_trace_has_a_lane_per_active_bank() {
        let events = sample_events();
        let json = chrome_trace(&events);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"name\":\"bank 0\""));
        assert!(json.contains("\"name\":\"bank 5\""));
        assert!(json.contains("\"name\":\"wave 0\""));
        assert!(json.contains("fallback"));
        // second wave starts after the first ends: (920+1365.3)/1000 µs
        assert!(json.contains(&format!("\"ts\":{}", f((920.0 + 1365.3) / 1000.0))));
    }

    #[test]
    fn prometheus_dump_renders_all_kinds() {
        let mut reg = crate::obs::metrics::Registry::new();
        let c = reg.counter("coord/ops");
        let g = reg.gauge("cache/hit_rate");
        let h = reg.hist("op/sim_ns");
        reg.inc(c, 42);
        reg.set_gauge(g, 0.75);
        reg.observe(h, 100);
        reg.observe(h, 200);
        let text = prometheus(&reg.snapshot());
        assert!(text.contains("# TYPE puma_coord_ops counter"));
        assert!(text.contains("puma_coord_ops 42"));
        assert!(text.contains("puma_cache_hit_rate 0.75"));
        assert!(text.contains("puma_op_sim_ns{quantile=\"0.99\"}"));
        assert!(text.contains("puma_op_sim_ns_count 2"));
    }
}
