//! Observability: metrics registry, sim-time span tracer, exporters.
//!
//! Three pieces (DESIGN.md §14):
//!
//! - [`metrics`] — named counters/gauges/log2-histograms behind integer
//!   id handles, cheap enough to stay on in every workload. The
//!   coordinator, allocator paths, program/column caches, and scratch
//!   pools all record into one [`metrics::Registry`] owned by the
//!   [`crate::coordinator::Coordinator`] (reachable as
//!   `System::coord.obs`).
//! - [`trace`] — a bounded ring of wave-granularity
//!   [`trace::WaveEvent`]s capturing each hazard wave's per-bank lanes
//!   and per-op `ExecStats` totals; O(waves) overhead, drop-counted
//!   when full.
//! - [`export`] — Chrome trace-event/Perfetto JSON (one lane per
//!   active bank), a replayable DDR-style command stream whose replay
//!   reproduces `CoordStats` totals byte-identically, and a
//!   Prometheus-style text dump. Surfaced by `puma trace --export`
//!   and `puma stats`.

pub mod export;
pub mod metrics;
pub mod trace;

use metrics::{HistId, Registry};
use trace::Tracer;

/// Pre-registered handles for the coordinator's own metrics.
#[derive(Debug, Clone, Copy)]
pub struct CoordMetricIds {
    /// Per-op simulated latency (ns), across all batches.
    pub op_sim_ns: HistId,
    /// Ops per hazard wave (the scheduler's extracted width).
    pub wave_ops: HistId,
    /// Per-wave simulated makespan (ns).
    pub wave_elapsed_ns: HistId,
}

/// The observability bundle the coordinator owns: one registry, one
/// tracer, and the coordinator's pre-registered metric ids.
#[derive(Debug)]
pub struct Obs {
    pub registry: Registry,
    pub tracer: Tracer,
    pub coord: CoordMetricIds,
}

impl Default for Obs {
    fn default() -> Self {
        Self::new()
    }
}

impl Obs {
    pub fn new() -> Self {
        let mut registry = Registry::new();
        let coord = CoordMetricIds {
            op_sim_ns: registry.hist("coord/op_sim_ns"),
            wave_ops: registry.hist("coord/wave_ops"),
            wave_elapsed_ns: registry.hist("coord/wave_elapsed_ns"),
        };
        Obs {
            registry,
            tracer: Tracer::default(),
            coord,
        }
    }
}
