//! Metrics registry: named counters, gauges, and log2-bucketed
//! histograms with mergeable snapshots.
//!
//! Hot paths pre-register by name once and then record through integer
//! id handles ([`CounterId`]/[`GaugeId`]/[`HistId`]) — a record is a
//! `Vec` index plus an array increment, no string hashing — so the
//! registry is cheap enough to stay on in every workload.

/// Number of log2 buckets per histogram. Bucket 0 holds the value 0;
/// bucket `k >= 1` holds `[2^(k-1), 2^k)`; the last bucket absorbs
/// everything above its floor.
pub const HIST_BUCKETS: usize = 64;

/// Handle for a registered counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);

/// Handle for a registered gauge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Handle for a registered histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistId(usize);

/// A log2-bucketed histogram of non-negative integer samples
/// (simulated nanoseconds, row counts, ...). Percentile estimates are
/// bucket upper bounds clamped to the observed max, so an estimate `e`
/// for a true value `v` always satisfies `v <= e < 2 v` (exact for 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hist {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    /// The bucket index covering `v`.
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of bucket `idx` (the percentile
    /// representative before clamping to the observed max).
    fn bucket_upper(idx: usize) -> u64 {
        if idx == 0 {
            0
        } else if idx >= 63 {
            u64::MAX
        } else {
            (1u64 << idx) - 1
        }
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a simulated-ns latency (negative values clamp to 0).
    pub fn record_ns(&mut self, ns: f64) {
        self.record(ns.max(0.0) as u64);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile estimate (`p` in `[0, 100]`). Returns 0
    /// on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_upper(idx).min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(90.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Fold another histogram in. Bucket-wise addition, so merging is
    /// associative and commutative (snapshots from shards can combine
    /// in any order).
    pub fn merge(&mut self, other: &Hist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// A point-in-time, mergeable copy of a registry's contents.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, Hist)>,
}

impl Snapshot {
    /// Merge another snapshot in: counters add, histograms merge,
    /// gauges take the other side's (latest-wins) value. Names absent
    /// on one side are carried over.
    pub fn merge(&mut self, other: &Snapshot) {
        for (name, v) in &other.counters {
            match self.counters.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += v,
                None => self.counters.push((name.clone(), *v)),
            }
        }
        for (name, v) in &other.gauges {
            match self.gauges.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine = *v,
                None => self.gauges.push((name.clone(), *v)),
            }
        }
        for (name, h) in &other.hists {
            match self.hists.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => mine.merge(h),
                None => self.hists.push((name.clone(), h.clone())),
            }
        }
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }
}

/// The registry proper. Registration (by name) is slow-path and
/// idempotent; recording through the returned ids is O(1).
#[derive(Debug, Default)]
pub struct Registry {
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, f64)>,
    hists: Vec<(String, Hist)>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or find) a counter named `name`.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) = self.counters.iter().position(|(n, _)| n == name) {
            return CounterId(i);
        }
        self.counters.push((name.to_string(), 0));
        CounterId(self.counters.len() - 1)
    }

    /// Register (or find) a gauge named `name`.
    pub fn gauge(&mut self, name: &str) -> GaugeId {
        if let Some(i) = self.gauges.iter().position(|(n, _)| n == name) {
            return GaugeId(i);
        }
        self.gauges.push((name.to_string(), 0.0));
        GaugeId(self.gauges.len() - 1)
    }

    /// Register (or find) a histogram named `name`.
    pub fn hist(&mut self, name: &str) -> HistId {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == name) {
            return HistId(i);
        }
        self.hists.push((name.to_string(), Hist::default()));
        HistId(self.hists.len() - 1)
    }

    pub fn inc(&mut self, id: CounterId, by: u64) {
        self.counters[id.0].1 += by;
    }

    pub fn set_gauge(&mut self, id: GaugeId, v: f64) {
        self.gauges[id.0].1 = v;
    }

    pub fn observe(&mut self, id: HistId, v: u64) {
        self.hists[id.0].1.record(v);
    }

    /// Observe a simulated-ns latency (negative clamps to 0).
    pub fn observe_ns(&mut self, id: HistId, ns: f64) {
        self.hists[id.0].1.record_ns(ns);
    }

    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.counters[id.0].1
    }

    pub fn gauge_value(&self, id: GaugeId) -> f64 {
        self.gauges[id.0].1
    }

    pub fn hist_value(&self, id: HistId) -> &Hist {
        &self.hists[id.0].1
    }

    /// Look a histogram up by name without registering it.
    pub fn hist_by_name(&self, name: &str) -> Option<&Hist> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self.counters.clone(),
            gauges: self.gauges.clone(),
            hists: self.hists.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent_and_ids_are_stable() {
        let mut r = Registry::new();
        let a = r.counter("a");
        let b = r.counter("b");
        assert_eq!(r.counter("a"), a);
        assert_ne!(a, b);
        r.inc(a, 3);
        r.inc(a, 2);
        assert_eq!(r.counter_value(a), 5);
        assert_eq!(r.counter_value(b), 0);
    }

    #[test]
    fn hist_percentiles_bracket_the_true_value() {
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        // True p50 of 1..=1000 is 500; the estimate must land in
        // [500, 1000) by the factor-of-2 bucket guarantee.
        let p50 = h.p50();
        assert!((500..1000).contains(&p50), "p50 = {p50}");
        let p99 = h.p99();
        assert!((990..=1000).contains(&p99), "p99 = {p99}");
        assert_eq!(h.count, 1000);
        assert_eq!(h.min, 1);
        assert_eq!(h.max, 1000);
    }

    #[test]
    fn hist_zero_and_max_edges() {
        let mut h = Hist::new();
        h.record(0);
        assert_eq!(h.p50(), 0);
        h.record(u64::MAX);
        assert_eq!(h.max, u64::MAX);
        assert_eq!(h.p99(), u64::MAX);
        assert_eq!(Hist::bucket_index(0), 0);
        assert_eq!(Hist::bucket_index(1), 1);
        assert_eq!(Hist::bucket_index(2), 2);
        assert_eq!(Hist::bucket_index(3), 2);
        assert_eq!(Hist::bucket_index(4), 3);
        assert_eq!(Hist::bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn snapshot_merge_adds_counters_and_merges_hists() {
        let mut r1 = Registry::new();
        let c1 = r1.counter("ops");
        let h1 = r1.hist("lat");
        r1.inc(c1, 7);
        r1.observe(h1, 10);
        let mut r2 = Registry::new();
        let c2 = r2.counter("ops");
        let h2 = r2.hist("lat");
        r2.inc(c2, 5);
        r2.observe(h2, 1000);

        let mut s = r1.snapshot();
        s.merge(&r2.snapshot());
        assert_eq!(s.counter("ops"), Some(12));
        let h = s.hist("lat").unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.min, 10);
        assert_eq!(h.max, 1000);
    }
}
