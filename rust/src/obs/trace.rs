//! Sim-time span tracer: a bounded ring of wave-granularity events.
//!
//! The coordinator records one [`WaveEvent`] per scheduled hazard wave
//! — not per row — so tracing overhead is O(waves). Each event carries
//! the wave's per-bank lanes (which banks burned how much sim time on
//! how many rows) and one [`OpSlot`] per op with its `ExecStats`-
//! derived totals, which is exactly enough to rebuild Perfetto
//! timelines and the DDR command stream in `obs::export` without
//! touching the hot path again.
//!
//! Capacity is bounded: once full, new events are *dropped* (newest-
//! dropped, so the retained prefix stays contiguous from boot) and
//! counted in [`Tracer::dropped`], so the sink can never distort what
//! it measures by growing without bound.

use crate::pud::isa::PudOp;

/// One bank's share of a wave: `busy_ns` of PUD work over `rows` rows
/// on dense bank id `bank` (see `DramGeometry::bank_id`).
#[derive(Debug, Clone, PartialEq)]
pub struct BankLane {
    pub bank: u32,
    pub rows: u64,
    pub busy_ns: f64,
}

/// One op's slot inside a wave, in submission order. The six totals
/// mirror `pud::exec::ExecStats` field-for-field so a replay can
/// re-absorb them into `CoordStats` byte-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct OpSlot {
    pub op: PudOp,
    pub pud_rows: u64,
    pub fallback_rows: u64,
    pub pud_bytes: u64,
    pub fallback_bytes: u64,
    pub pud_ns: f64,
    pub fallback_ns: f64,
}

/// One scheduled hazard wave on the sim-time axis.
#[derive(Debug, Clone, PartialEq)]
pub struct WaveEvent {
    /// Batch index (`PipelineStats::batches` at submission).
    pub batch: u64,
    /// Global wave index, aligned with `PipelineStats::waves`.
    pub wave: u64,
    /// Sim-time at which this wave begins (waves serialize).
    pub start_ns: f64,
    /// Bank-parallel PUD leg duration (incl. dispatch overhead).
    pub pud_ns: f64,
    /// Host fallback leg duration, serialized after the PUD leg.
    pub fallback_ns: f64,
    /// Per-bank PUD load, sorted by bank id.
    pub lanes: Vec<BankLane>,
    /// Per-op totals, in submission order.
    pub ops: Vec<OpSlot>,
}

impl WaveEvent {
    pub fn elapsed_ns(&self) -> f64 {
        self.pud_ns + self.fallback_ns
    }

    pub fn end_ns(&self) -> f64 {
        self.start_ns + self.elapsed_ns()
    }
}

/// The bounded event sink.
#[derive(Debug)]
pub struct Tracer {
    events: Vec<WaveEvent>,
    capacity: usize,
    enabled: bool,
    /// Events rejected because the ring was full.
    pub dropped: u64,
    /// Total waves offered (recorded + dropped) — stays aligned with
    /// `PipelineStats::waves` while the tracer is enabled.
    pub total_waves: u64,
    /// Sim-time cursor: end of the last recorded wave.
    pub now_ns: f64,
}

/// Default ring capacity (waves, not rows — plenty for every workload
/// in this repo; `puma trace` raises it explicitly).
pub const DEFAULT_CAPACITY: usize = 4096;

impl Default for Tracer {
    fn default() -> Self {
        Self::new(DEFAULT_CAPACITY)
    }
}

impl Tracer {
    pub fn new(capacity: usize) -> Self {
        Tracer {
            events: Vec::new(),
            capacity,
            enabled: true,
            dropped: 0,
            total_waves: 0,
            now_ns: 0.0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Turn capture on/off. Disabling stops the coordinator from even
    /// assembling events (the overhead-gate path in the bench).
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Grow/shrink the ring bound. Existing events are kept (truncated
    /// if over the new bound, counted as drops).
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity;
        if self.events.len() > capacity {
            self.dropped += (self.events.len() - capacity) as u64;
            self.events.truncate(capacity);
        }
    }

    pub fn events(&self) -> &[WaveEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Offer a wave. The tracer assigns the global wave id, advances
    /// the sim-time cursor, and either stores the event or counts a
    /// drop when the ring is full.
    pub fn record(&mut self, mut ev: WaveEvent) {
        ev.wave = self.total_waves;
        ev.start_ns = self.now_ns;
        self.now_ns = ev.end_ns();
        self.total_waves += 1;
        if self.events.len() < self.capacity {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    /// Reset events, drop/wave counters, and the sim-time cursor.
    pub fn clear(&mut self) {
        self.events.clear();
        self.dropped = 0;
        self.total_waves = 0;
        self.now_ns = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(pud_ns: f64, fallback_ns: f64) -> WaveEvent {
        WaveEvent {
            batch: 0,
            wave: 0,
            start_ns: 0.0,
            pud_ns,
            fallback_ns,
            lanes: vec![BankLane {
                bank: 0,
                rows: 1,
                busy_ns: pud_ns,
            }],
            ops: Vec::new(),
        }
    }

    #[test]
    fn ring_drops_newest_and_counts() {
        let mut t = Tracer::new(4);
        for _ in 0..7 {
            t.record(ev(10.0, 0.0));
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped, 3);
        assert_eq!(t.total_waves, 7);
        // The retained prefix is contiguous from wave 0.
        let ids: Vec<u64> = t.events().iter().map(|e| e.wave).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        // The cursor kept advancing through the drops.
        assert_eq!(t.now_ns, 70.0);
    }

    #[test]
    fn cursor_serializes_waves() {
        let mut t = Tracer::new(8);
        t.record(ev(100.0, 50.0));
        t.record(ev(25.0, 0.0));
        let e = t.events();
        assert_eq!(e[0].start_ns, 0.0);
        assert_eq!(e[0].end_ns(), 150.0);
        assert_eq!(e[1].start_ns, 150.0);
        assert_eq!(e[1].end_ns(), 175.0);
    }

    #[test]
    fn clear_resets_everything() {
        let mut t = Tracer::new(1);
        t.record(ev(1.0, 0.0));
        t.record(ev(1.0, 0.0));
        assert_eq!(t.dropped, 1);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped, 0);
        assert_eq!(t.total_waves, 0);
        assert_eq!(t.now_ns, 0.0);
    }
}
