//! Simulated OS memory substrate.
//!
//! PUMA is a kernel-level allocator; its behaviour depends on the OS
//! machinery around it, which we therefore model with Linux semantics
//! (DESIGN.md §6):
//!
//! * [`buddy`] — physical frame allocator (per-order free lists, split
//!   and coalesce), as in the Linux page allocator.
//! * [`page_table`] — radix page tables with 4 KiB and 2 MiB leaves
//!   (Sv39-like three-level walk).
//! * [`vma`] — per-process virtual-area manager: `mmap`-style region
//!   allocation, fixed mapping, unmapping, and the *re-mmap* primitive
//!   PUMA uses to stitch scattered regions into contiguous VA.
//! * [`hugepage`] — the boot-time huge-page pool (hugetlbfs-like):
//!   physically contiguous, 2 MiB aligned.
//! * [`process`] — an address space bundling the above.

pub mod buddy;
pub mod hugepage;
pub mod page_table;
pub mod process;
pub mod vma;

/// Base page size (4 KiB).
pub const PAGE_SIZE: u64 = 4096;
/// Huge page size (2 MiB).
pub const HUGE_PAGE_SIZE: u64 = 2 << 20;
/// Buddy order of a huge page (2 MiB / 4 KiB = 512 = 2^9).
pub const HUGE_PAGE_ORDER: u8 = 9;

/// Round `v` up to a multiple of `align` (power of two).
#[inline]
pub fn align_up(v: u64, align: u64) -> u64 {
    debug_assert!(align.is_power_of_two());
    (v + align - 1) & !(align - 1)
}

/// Round `v` down to a multiple of `align` (power of two).
#[inline]
pub fn align_down(v: u64, align: u64) -> u64 {
    debug_assert!(align.is_power_of_two());
    v & !(align - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_helpers() {
        assert_eq!(align_up(0, 4096), 0);
        assert_eq!(align_up(1, 4096), 4096);
        assert_eq!(align_up(4096, 4096), 4096);
        assert_eq!(align_down(4097, 4096), 4096);
        assert_eq!(align_down(4095, 4096), 0);
    }

    #[test]
    fn huge_page_constants_consistent() {
        assert_eq!(PAGE_SIZE << HUGE_PAGE_ORDER, HUGE_PAGE_SIZE);
    }
}
