//! Buddy physical-frame allocator (Linux-page-allocator style).
//!
//! Per-order free lists over a physical frame range; allocation splits
//! higher orders, freeing coalesces with the buddy block. Order 0 is a
//! 4 KiB frame; order 9 a 2 MiB huge page.
//!
//! The free lists are LIFO, and [`BuddyAllocator::churn`] simulates a
//! long-running system: it allocates and frees random blocks so the
//! lists end up in scrambled order. That is what makes the simulated
//! `malloc` realistic — consecutive virtual pages of a fresh process
//! get physically scattered frames, which is exactly why the paper
//! measures 0% PUD-executable operations under `malloc`.

use anyhow::{bail, Result};
use rustc_hash::FxHashSet;

use crate::util::rng::Pcg64;

use super::PAGE_SIZE;

/// Maximum block order (2^10 frames = 4 MiB blocks).
pub const MAX_ORDER: u8 = 10;

/// A physical frame number (frame address = pfn * PAGE_SIZE).
pub type Pfn = u64;

/// Buddy allocator over frames `[0, nframes)`.
pub struct BuddyAllocator {
    nframes: u64,
    /// free_lists[order] holds the first PFN of each free 2^order block.
    free_lists: Vec<Vec<Pfn>>,
    /// All free (pfn, order) blocks for O(1) buddy lookup on free().
    free_index: FxHashSet<(Pfn, u8)>,
    /// Outstanding allocations, for double-free detection and
    /// invariant checks.
    outstanding: FxHashSet<(Pfn, u8)>,
    /// Blocks pinned by [`BuddyAllocator::churn`] to model long-lived allocations of
    /// other processes (released by [`BuddyAllocator::release_pinned`]).
    pinned: Vec<(Pfn, u8)>,
    pub allocated_frames: u64,
}

impl BuddyAllocator {
    /// Create an allocator with every frame free. `nframes` must be a
    /// multiple of the max block size so the initial free lists tile
    /// exactly.
    pub fn new(nframes: u64) -> Result<Self> {
        let block = 1u64 << MAX_ORDER;
        if nframes == 0 || nframes % block != 0 {
            bail!("nframes {nframes} must be a nonzero multiple of {block}");
        }
        let mut a = Self {
            nframes,
            free_lists: vec![Vec::new(); MAX_ORDER as usize + 1],
            free_index: FxHashSet::default(),
            outstanding: FxHashSet::default(),
            pinned: Vec::new(),
            allocated_frames: 0,
        };
        let mut pfn = 0;
        while pfn < nframes {
            a.push_free(pfn, MAX_ORDER);
            pfn += block;
        }
        Ok(a)
    }

    /// Allocator sized to back `bytes` of physical memory.
    pub fn with_capacity_bytes(bytes: u64) -> Result<Self> {
        Self::new(bytes.div_ceil(PAGE_SIZE))
    }

    pub fn nframes(&self) -> u64 {
        self.nframes
    }

    pub fn free_frames(&self) -> u64 {
        self.nframes - self.allocated_frames
    }

    fn push_free(&mut self, pfn: Pfn, order: u8) {
        self.free_lists[order as usize].push(pfn);
        self.free_index.insert((pfn, order));
    }

    /// Remove a specific free block (used for coalescing); true if it
    /// was present.
    fn take_free(&mut self, pfn: Pfn, order: u8) -> bool {
        if self.free_index.remove(&(pfn, order)) {
            let list = &mut self.free_lists[order as usize];
            let idx = list
                .iter()
                .rposition(|&p| p == pfn)
                .expect("index and list agree");
            list.swap_remove(idx);
            true
        } else {
            false
        }
    }

    /// Allocate a 2^order-frame block; the returned PFN is aligned to
    /// the block size.
    pub fn alloc(&mut self, order: u8) -> Result<Pfn> {
        if order > MAX_ORDER {
            bail!("order {order} > MAX_ORDER {MAX_ORDER}");
        }
        // find the smallest order with a free block
        let mut o = order;
        while o <= MAX_ORDER && self.free_lists[o as usize].is_empty() {
            o += 1;
        }
        if o > MAX_ORDER {
            bail!("out of physical memory (order {order})");
        }
        let pfn = self.free_lists[o as usize].pop().expect("nonempty");
        self.free_index.remove(&(pfn, o));
        // split down to the requested order, freeing the upper halves
        while o > order {
            o -= 1;
            self.push_free(pfn + (1 << o), o);
        }
        self.allocated_frames += 1 << order;
        self.outstanding.insert((pfn, order));
        Ok(pfn)
    }

    /// Free a block previously returned by [`BuddyAllocator::alloc`] with this order.
    pub fn free(&mut self, pfn: Pfn, order: u8) {
        assert!(order <= MAX_ORDER);
        assert_eq!(pfn % (1 << order), 0, "pfn {pfn} misaligned for order {order}");
        assert!(pfn + (1 << order) <= self.nframes, "pfn beyond range");
        assert!(
            self.outstanding.remove(&(pfn, order)),
            "double free (or never allocated): pfn {pfn} order {order}"
        );
        self.allocated_frames -= 1 << order;
        let mut pfn = pfn;
        let mut order = order;
        // coalesce while the buddy is free
        while order < MAX_ORDER {
            let buddy = pfn ^ (1u64 << order);
            if !self.take_free(buddy, order) {
                break;
            }
            pfn = pfn.min(buddy);
            order += 1;
        }
        self.push_free(pfn, order);
    }

    /// Simulate allocator aging: perform `rounds` random alloc/free
    /// pairs so free lists lose their boot-time ordering, and *pin*
    /// roughly half of the touched blocks to model other processes'
    /// long-lived allocations (full release would simply coalesce
    /// everything back into ordered max-order blocks). Afterwards,
    /// consecutive [`BuddyAllocator::alloc`] calls return scattered frames — the
    /// realistic starting condition for the malloc baseline.
    pub fn churn(&mut self, rng: &mut Pcg64, rounds: usize) {
        let mut held: Vec<(Pfn, u8)> = Vec::new();
        for _ in 0..rounds {
            if held.is_empty() || (rng.chance(0.6) && self.free_frames() > (1 << MAX_ORDER)) {
                let order = rng.below(4) as u8; // small blocks scramble most
                if let Ok(pfn) = self.alloc(order) {
                    held.push((pfn, order));
                }
            } else {
                let idx = rng.below(held.len() as u64) as usize;
                let (pfn, order) = held.swap_remove(idx);
                self.free(pfn, order);
            }
        }
        // keep ~half pinned (fragmentation), release the rest randomly
        rng.shuffle(&mut held);
        let keep = held.len() / 2;
        for (pfn, order) in held.drain(keep..).collect::<Vec<_>>() {
            self.free(pfn, order);
        }
        self.pinned.extend(held);
    }

    /// Frames currently pinned by [`BuddyAllocator::churn`].
    pub fn pinned_frames(&self) -> u64 {
        self.pinned.iter().map(|&(_, o)| 1u64 << o).sum()
    }

    /// Release every block pinned by [`BuddyAllocator::churn`].
    pub fn release_pinned(&mut self) {
        for (pfn, order) in std::mem::take(&mut self.pinned) {
            self.free(pfn, order);
        }
    }

    /// Sanity check: free lists tile disjoint frames and counters add up
    /// (test/property support).
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = FxHashSet::default();
        let mut free = 0u64;
        for (order, list) in self.free_lists.iter().enumerate() {
            for &pfn in list {
                if pfn % (1 << order) != 0 {
                    bail!("free block {pfn} misaligned for order {order}");
                }
                for f in pfn..pfn + (1 << order) {
                    if !seen.insert(f) {
                        bail!("frame {f} on two free lists");
                    }
                }
                if !self.free_index.contains(&(pfn, order as u8)) {
                    bail!("list/index mismatch at ({pfn}, {order})");
                }
                free += 1 << order;
            }
        }
        if free != self.free_frames() {
            bail!(
                "free list total {free} != counter {}",
                self.free_frames()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_allocator_is_fully_free() {
        let a = BuddyAllocator::new(2048).unwrap();
        assert_eq!(a.free_frames(), 2048);
        a.check_invariants().unwrap();
    }

    #[test]
    fn rejects_bad_sizes() {
        assert!(BuddyAllocator::new(0).is_err());
        assert!(BuddyAllocator::new(1000).is_err());
    }

    #[test]
    fn alloc_returns_aligned_blocks() {
        let mut a = BuddyAllocator::new(2048).unwrap();
        for order in [0u8, 1, 3, 9] {
            let pfn = a.alloc(order).unwrap();
            assert_eq!(pfn % (1 << order), 0, "order {order}");
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn split_and_coalesce_roundtrip() {
        let mut a = BuddyAllocator::new(1024).unwrap();
        let p0 = a.alloc(0).unwrap();
        assert_eq!(a.free_frames(), 1023);
        a.free(p0, 0);
        assert_eq!(a.free_frames(), 1024);
        a.check_invariants().unwrap();
        // after full coalescing a max-order alloc succeeds again
        let big = a.alloc(MAX_ORDER).unwrap();
        assert_eq!(big % (1 << MAX_ORDER), 0);
    }

    #[test]
    fn exhaustion_errors_cleanly() {
        let mut a = BuddyAllocator::new(1024).unwrap();
        let _ = a.alloc(MAX_ORDER).unwrap();
        assert!(a.alloc(0).is_err());
    }

    #[test]
    fn distinct_blocks_never_overlap() {
        let mut a = BuddyAllocator::new(2048).unwrap();
        let mut frames = FxHashSet::default();
        for _ in 0..64 {
            let pfn = a.alloc(2).unwrap(); // 4-frame blocks
            for f in pfn..pfn + 4 {
                assert!(frames.insert(f), "overlap at {f}");
            }
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn churn_scrambles_allocation_order() {
        let mut a = BuddyAllocator::new(4096).unwrap();
        let mut rng = Pcg64::new(42);
        a.churn(&mut rng, 2000);
        a.check_invariants().unwrap();
        assert_eq!(
            a.free_frames() + a.pinned_frames(),
            4096,
            "churn accounts for every frame"
        );
        assert!(a.pinned_frames() > 0, "churn pins some blocks");
        // consecutive allocs should now be non-consecutive frames
        let xs: Vec<Pfn> = (0..8).map(|_| a.alloc(0).unwrap()).collect();
        let consecutive = xs.windows(2).filter(|w| w[1] == w[0] + 1).count();
        assert!(
            consecutive < 4,
            "free lists still ordered after churn: {xs:?}"
        );
        // and pinned blocks can be released to restore a clean machine
        for pfn in xs {
            a.free(pfn, 0);
        }
        a.release_pinned();
        assert_eq!(a.free_frames(), 4096);
        a.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics_in_debug() {
        let mut a = BuddyAllocator::new(1024).unwrap();
        let p = a.alloc(0).unwrap();
        a.free(p, 0);
        a.free(p, 0);
    }
}
