//! Radix page tables with 4 KiB and 2 MiB leaves (Sv39-like walk).
//!
//! Three levels of 512-entry tables over a 39-bit virtual space, as in
//! RISC-V Sv39 (the paper's evaluation platform is an emulated RISC-V
//! machine). A level-1 entry may be a 2 MiB leaf (huge page) or point
//! to a level-0 table of 4 KiB leaves.

use anyhow::{bail, Result};

use super::{HUGE_PAGE_SIZE, PAGE_SIZE};

/// Mapping granularity of a leaf entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageKind {
    Base, // 4 KiB
    Huge, // 2 MiB
}

impl PageKind {
    pub fn bytes(&self) -> u64 {
        match self {
            PageKind::Base => PAGE_SIZE,
            PageKind::Huge => HUGE_PAGE_SIZE,
        }
    }
}

/// A translated physical address plus its mapping granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Translation {
    pub paddr: u64,
    pub kind: PageKind,
}

#[derive(Debug)]
enum Entry {
    Empty,
    Table(Box<Level>),
    /// Leaf: physical base address of the mapped page.
    Leaf(u64),
}

#[derive(Debug)]
struct Level {
    entries: Vec<Entry>,
}

impl Level {
    fn new() -> Self {
        Self {
            entries: (0..512).map(|_| Entry::Empty).collect(),
        }
    }
}

/// One process's page table.
#[derive(Debug)]
pub struct PageTable {
    root: Level, // level 2 (1 GiB per entry)
    pub mapped_base_pages: u64,
    pub mapped_huge_pages: u64,
}

const VA_BITS: u32 = 39;

fn vpn(vaddr: u64, level: u32) -> usize {
    ((vaddr >> (12 + 9 * level)) & 0x1FF) as usize
}

impl Default for PageTable {
    fn default() -> Self {
        Self::new()
    }
}

impl PageTable {
    pub fn new() -> Self {
        Self {
            root: Level::new(),
            mapped_base_pages: 0,
            mapped_huge_pages: 0,
        }
    }

    fn check_va(vaddr: u64) -> Result<()> {
        if vaddr >> VA_BITS != 0 {
            bail!("virtual address {vaddr:#x} beyond Sv39 range");
        }
        Ok(())
    }

    /// Map a page of `kind` at `vaddr` -> `paddr` (both aligned).
    /// Fails on misalignment or an existing conflicting mapping.
    pub fn map(&mut self, vaddr: u64, paddr: u64, kind: PageKind) -> Result<()> {
        Self::check_va(vaddr)?;
        let sz = kind.bytes();
        if vaddr % sz != 0 || paddr % sz != 0 {
            bail!("map misaligned: va {vaddr:#x} pa {paddr:#x} size {sz:#x}");
        }
        let l2 = &mut self.root.entries[vpn(vaddr, 2)];
        let l1_table = match l2 {
            Entry::Empty => {
                *l2 = Entry::Table(Box::new(Level::new()));
                match l2 {
                    Entry::Table(t) => t,
                    _ => unreachable!(),
                }
            }
            Entry::Table(t) => t,
            Entry::Leaf(_) => bail!("1 GiB leaf conflicts at {vaddr:#x}"),
        };
        let l1 = &mut l1_table.entries[vpn(vaddr, 1)];
        match kind {
            PageKind::Huge => match l1 {
                Entry::Empty => {
                    *l1 = Entry::Leaf(paddr);
                    self.mapped_huge_pages += 1;
                    Ok(())
                }
                _ => bail!("mapping conflict at {vaddr:#x} (huge)"),
            },
            PageKind::Base => {
                let l0_table = match l1 {
                    Entry::Empty => {
                        *l1 = Entry::Table(Box::new(Level::new()));
                        match l1 {
                            Entry::Table(t) => t,
                            _ => unreachable!(),
                        }
                    }
                    Entry::Table(t) => t,
                    Entry::Leaf(_) => {
                        bail!("base map under huge leaf at {vaddr:#x}")
                    }
                };
                let l0 = &mut l0_table.entries[vpn(vaddr, 0)];
                match l0 {
                    Entry::Empty => {
                        *l0 = Entry::Leaf(paddr);
                        self.mapped_base_pages += 1;
                        Ok(())
                    }
                    _ => bail!("mapping conflict at {vaddr:#x} (base)"),
                }
            }
        }
    }

    /// Remove the mapping containing `vaddr`; returns what was mapped.
    pub fn unmap(&mut self, vaddr: u64) -> Result<Translation> {
        Self::check_va(vaddr)?;
        let l2 = &mut self.root.entries[vpn(vaddr, 2)];
        let l1_table = match l2 {
            Entry::Table(t) => t,
            _ => bail!("unmap: nothing mapped at {vaddr:#x}"),
        };
        let l1 = &mut l1_table.entries[vpn(vaddr, 1)];
        match l1 {
            Entry::Leaf(paddr) => {
                let t = Translation {
                    paddr: *paddr,
                    kind: PageKind::Huge,
                };
                *l1 = Entry::Empty;
                self.mapped_huge_pages -= 1;
                Ok(t)
            }
            Entry::Table(l0_table) => {
                let l0 = &mut l0_table.entries[vpn(vaddr, 0)];
                match l0 {
                    Entry::Leaf(paddr) => {
                        let t = Translation {
                            paddr: *paddr,
                            kind: PageKind::Base,
                        };
                        *l0 = Entry::Empty;
                        self.mapped_base_pages -= 1;
                        Ok(t)
                    }
                    _ => bail!("unmap: nothing mapped at {vaddr:#x}"),
                }
            }
            Entry::Empty => bail!("unmap: nothing mapped at {vaddr:#x}"),
        }
    }

    /// Translate an arbitrary virtual address (any offset).
    pub fn translate(&self, vaddr: u64) -> Option<Translation> {
        if vaddr >> VA_BITS != 0 {
            return None;
        }
        let l2 = &self.root.entries[vpn(vaddr, 2)];
        let l1_table = match l2 {
            Entry::Table(t) => t,
            _ => return None,
        };
        match &l1_table.entries[vpn(vaddr, 1)] {
            Entry::Leaf(paddr) => Some(Translation {
                paddr: paddr + (vaddr & (HUGE_PAGE_SIZE - 1)),
                kind: PageKind::Huge,
            }),
            Entry::Table(l0_table) => match &l0_table.entries[vpn(vaddr, 0)] {
                Entry::Leaf(paddr) => Some(Translation {
                    paddr: paddr + (vaddr & (PAGE_SIZE - 1)),
                    kind: PageKind::Base,
                }),
                _ => None,
            },
            Entry::Empty => None,
        }
    }

    /// Is the whole `[vaddr, vaddr+len)` range mapped?
    pub fn range_mapped(&self, vaddr: u64, len: u64) -> bool {
        let mut cur = super::align_down(vaddr, PAGE_SIZE);
        let end = vaddr + len;
        while cur < end {
            match self.translate(cur) {
                Some(t) => {
                    let page = match t.kind {
                        PageKind::Base => PAGE_SIZE,
                        PageKind::Huge => HUGE_PAGE_SIZE,
                    };
                    cur = super::align_down(cur, page) + page;
                }
                None => return false,
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_map_translate_roundtrip() {
        let mut pt = PageTable::new();
        pt.map(0x1000, 0xABC000, PageKind::Base).unwrap();
        let t = pt.translate(0x1234).unwrap();
        assert_eq!(t.paddr, 0xABC234);
        assert_eq!(t.kind, PageKind::Base);
        assert_eq!(pt.translate(0x2000), None);
    }

    #[test]
    fn huge_map_translates_interior_offsets() {
        let mut pt = PageTable::new();
        pt.map(HUGE_PAGE_SIZE, 4 * HUGE_PAGE_SIZE, PageKind::Huge)
            .unwrap();
        let t = pt.translate(HUGE_PAGE_SIZE + 0x12345).unwrap();
        assert_eq!(t.paddr, 4 * HUGE_PAGE_SIZE + 0x12345);
        assert_eq!(t.kind, PageKind::Huge);
    }

    #[test]
    fn rejects_misaligned_and_conflicting() {
        let mut pt = PageTable::new();
        assert!(pt.map(0x1001, 0x2000, PageKind::Base).is_err());
        assert!(pt.map(0x1000, 0x2001, PageKind::Base).is_err());
        pt.map(0x1000, 0x2000, PageKind::Base).unwrap();
        assert!(pt.map(0x1000, 0x3000, PageKind::Base).is_err());
        // base page under an established huge leaf
        pt.map(HUGE_PAGE_SIZE, 0, PageKind::Huge).unwrap();
        assert!(pt
            .map(HUGE_PAGE_SIZE + PAGE_SIZE, 0x4000, PageKind::Base)
            .is_err());
    }

    #[test]
    fn rejects_va_beyond_sv39() {
        let mut pt = PageTable::new();
        assert!(pt.map(1 << 39, 0, PageKind::Base).is_err());
        assert_eq!(pt.translate(1 << 40), None);
    }

    #[test]
    fn unmap_returns_previous_mapping() {
        let mut pt = PageTable::new();
        pt.map(0x4000, 0x8000, PageKind::Base).unwrap();
        let t = pt.unmap(0x4000).unwrap();
        assert_eq!(t.paddr, 0x8000);
        assert_eq!(pt.translate(0x4000), None);
        assert!(pt.unmap(0x4000).is_err());
        assert_eq!(pt.mapped_base_pages, 0);
    }

    #[test]
    fn counters_track_mappings() {
        let mut pt = PageTable::new();
        pt.map(0, 0, PageKind::Base).unwrap();
        pt.map(PAGE_SIZE, PAGE_SIZE, PageKind::Base).unwrap();
        pt.map(HUGE_PAGE_SIZE, 0, PageKind::Huge).unwrap();
        assert_eq!(pt.mapped_base_pages, 2);
        assert_eq!(pt.mapped_huge_pages, 1);
        pt.unmap(HUGE_PAGE_SIZE).unwrap();
        assert_eq!(pt.mapped_huge_pages, 0);
    }

    #[test]
    fn range_mapped_mixed_granularity() {
        let mut pt = PageTable::new();
        // map [2M, 4M) huge and [4M, 4M+8K) base
        pt.map(HUGE_PAGE_SIZE, 0, PageKind::Huge).unwrap();
        pt.map(2 * HUGE_PAGE_SIZE, HUGE_PAGE_SIZE, PageKind::Base)
            .unwrap();
        pt.map(
            2 * HUGE_PAGE_SIZE + PAGE_SIZE,
            HUGE_PAGE_SIZE + PAGE_SIZE,
            PageKind::Base,
        )
        .unwrap();
        assert!(pt.range_mapped(HUGE_PAGE_SIZE, HUGE_PAGE_SIZE + 2 * PAGE_SIZE));
        assert!(!pt.range_mapped(HUGE_PAGE_SIZE, HUGE_PAGE_SIZE + 3 * PAGE_SIZE));
        assert!(!pt.range_mapped(0, PAGE_SIZE));
    }

    #[test]
    fn remap_pattern_for_puma() {
        // PUMA's re-mmap: unmap a page and map a different physical
        // frame at the same VA.
        let mut pt = PageTable::new();
        pt.map(0x10000, 0xAAAA000, PageKind::Base).unwrap();
        pt.unmap(0x10000).unwrap();
        pt.map(0x10000, 0xBBBB000, PageKind::Base).unwrap();
        assert_eq!(pt.translate(0x10000).unwrap().paddr, 0xBBBB000);
    }
}
