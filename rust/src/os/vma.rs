//! Virtual-area manager: mmap-style region bookkeeping per process.
//!
//! Tracks which virtual ranges are in use, finds free ranges with a
//! requested alignment, and supports the fixed-address re-mapping PUMA
//! needs when it stitches memory regions from different huge pages
//! into one virtually-contiguous allocation.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::{align_up, PAGE_SIZE};

/// What a VMA is backed by (bookkeeping only; the page table holds the
/// actual translations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VmaKind {
    /// Ordinary anonymous memory (malloc arenas, stacks, ...).
    Anon,
    /// hugetlbfs-style mapping.
    Huge,
    /// A PUMA allocation (pim_alloc / pim_alloc_align).
    Pud,
}

/// One virtual memory area.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Vma {
    pub start: u64,
    pub len: u64,
    pub kind: VmaKind,
}

impl Vma {
    pub fn end(&self) -> u64 {
        self.start + self.len
    }
}

/// The per-process VMA set.
#[derive(Debug, Default)]
pub struct VmaManager {
    /// start -> Vma, non-overlapping, page-aligned.
    areas: BTreeMap<u64, Vma>,
}

/// Bottom of the mmap area (keep low VA clear, like Linux).
pub const MMAP_BASE: u64 = 0x10_0000_0000 >> 3; // 2 GiB, inside Sv39
/// Top of the usable VA (Sv39 user half).
pub const MMAP_TOP: u64 = 1 << 38;

impl VmaManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Vma> {
        self.areas.values()
    }

    pub fn len(&self) -> usize {
        self.areas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.areas.is_empty()
    }

    /// The VMA containing `vaddr`, if any.
    pub fn find(&self, vaddr: u64) -> Option<&Vma> {
        self.areas
            .range(..=vaddr)
            .next_back()
            .map(|(_, v)| v)
            .filter(|v| vaddr < v.end())
    }

    fn overlaps(&self, start: u64, len: u64) -> bool {
        let end = start + len;
        if let Some((_, prev)) = self.areas.range(..start).next_back() {
            if prev.end() > start {
                return true;
            }
        }
        self.areas.range(start..end).next().is_some()
    }

    /// Find a free, `align`-aligned range of `len` bytes (both page
    /// multiples) scanning upward from `MMAP_BASE`.
    pub fn find_free(&self, len: u64, align: u64) -> Result<u64> {
        if len == 0 || len % PAGE_SIZE != 0 {
            bail!("find_free: len {len} not a positive page multiple");
        }
        let align = align.max(PAGE_SIZE);
        if !align.is_power_of_two() {
            bail!("find_free: align {align} not a power of two");
        }
        let mut candidate = align_up(MMAP_BASE, align);
        // walk VMAs in order, jumping over collisions
        loop {
            if candidate + len > MMAP_TOP {
                bail!("virtual address space exhausted");
            }
            if !self.overlaps(candidate, len) {
                return Ok(candidate);
            }
            // jump past the blocking VMA
            let (_, blocker) = self
                .areas
                .range(..candidate + len)
                .next_back()
                .expect("overlap implies a blocker");
            candidate = align_up(blocker.end(), align);
        }
    }

    /// Reserve a range at a chosen address (mmap MAP_FIXED semantics,
    /// but refusing overlap instead of clobbering).
    pub fn map_fixed(&mut self, start: u64, len: u64, kind: VmaKind) -> Result<()> {
        if start % PAGE_SIZE != 0 || len == 0 || len % PAGE_SIZE != 0 {
            bail!("map_fixed: misaligned ({start:#x}, {len:#x})");
        }
        if self.overlaps(start, len) {
            bail!("map_fixed: range [{start:#x}, +{len:#x}) overlaps");
        }
        self.areas.insert(
            start,
            Vma {
                start,
                len,
                kind,
            },
        );
        Ok(())
    }

    /// Allocate a fresh range (find + map).
    pub fn map(&mut self, len: u64, align: u64, kind: VmaKind) -> Result<u64> {
        let len = align_up(len, PAGE_SIZE);
        let start = self.find_free(len, align)?;
        self.map_fixed(start, len, kind)?;
        Ok(start)
    }

    /// Remove the VMA starting exactly at `start`.
    pub fn unmap(&mut self, start: u64) -> Result<Vma> {
        self.areas
            .remove(&start)
            .ok_or_else(|| anyhow::anyhow!("unmap: no VMA at {start:#x}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_finds_disjoint_ranges() {
        let mut m = VmaManager::new();
        let a = m.map(3 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        let b = m.map(PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        assert!(b >= a + 3 * PAGE_SIZE || a >= b + PAGE_SIZE);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn alignment_respected() {
        let mut m = VmaManager::new();
        let a = m
            .map(PAGE_SIZE, 1 << 21, VmaKind::Huge)
            .unwrap();
        assert_eq!(a % (1 << 21), 0);
    }

    #[test]
    fn find_locates_containing_vma() {
        let mut m = VmaManager::new();
        let a = m.map(2 * PAGE_SIZE, PAGE_SIZE, VmaKind::Pud).unwrap();
        assert_eq!(m.find(a).unwrap().start, a);
        assert_eq!(m.find(a + PAGE_SIZE + 5).unwrap().start, a);
        assert!(m.find(a + 2 * PAGE_SIZE).is_none());
        assert!(m.find(0).is_none());
    }

    #[test]
    fn map_fixed_rejects_overlap() {
        let mut m = VmaManager::new();
        m.map_fixed(MMAP_BASE, 4 * PAGE_SIZE, VmaKind::Anon).unwrap();
        assert!(m
            .map_fixed(MMAP_BASE + PAGE_SIZE, PAGE_SIZE, VmaKind::Anon)
            .is_err());
        // adjacent is fine
        m.map_fixed(MMAP_BASE + 4 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon)
            .unwrap();
    }

    #[test]
    fn map_fixed_rejects_misaligned() {
        let mut m = VmaManager::new();
        assert!(m.map_fixed(123, PAGE_SIZE, VmaKind::Anon).is_err());
        assert!(m.map_fixed(PAGE_SIZE, 100, VmaKind::Anon).is_err());
        assert!(m.map_fixed(PAGE_SIZE, 0, VmaKind::Anon).is_err());
    }

    #[test]
    fn unmap_then_remap_reuses_space() {
        let mut m = VmaManager::new();
        let a = m.map(PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        let vma = m.unmap(a).unwrap();
        assert_eq!(vma.start, a);
        let b = m.map(PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        assert_eq!(a, b, "freed range is reused");
    }

    #[test]
    fn find_free_skips_over_blockers() {
        let mut m = VmaManager::new();
        let base = align_up(MMAP_BASE, PAGE_SIZE);
        m.map_fixed(base, PAGE_SIZE, VmaKind::Anon).unwrap();
        m.map_fixed(base + 2 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon)
            .unwrap();
        // a 2-page request cannot use the 1-page hole at base+1
        let got = m.find_free(2 * PAGE_SIZE, PAGE_SIZE).unwrap();
        assert!(got >= base + 3 * PAGE_SIZE);
        // but a 1-page request can
        let got1 = m.find_free(PAGE_SIZE, PAGE_SIZE).unwrap();
        assert_eq!(got1, base + PAGE_SIZE);
    }

    #[test]
    fn exhaustion_is_an_error() {
        let m = VmaManager::new();
        assert!(m.find_free(MMAP_TOP, PAGE_SIZE).is_err());
    }
}
