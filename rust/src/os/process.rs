//! A simulated process: address space = page table + VMA set.
//!
//! Processes give each workload its own virtual address space on the
//! shared physical machine, and provide the translate-and-access
//! helpers the coordinator uses to turn virtual bulk-op operands into
//! physical extents.

use anyhow::{bail, Context, Result};

use super::page_table::{PageKind, PageTable, Translation};
use super::vma::{Vma, VmaKind, VmaManager};
use super::{HUGE_PAGE_SIZE, PAGE_SIZE};

/// Process id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Pid(pub u32);

/// A simulated process address space.
#[derive(Debug)]
pub struct Process {
    pub pid: Pid,
    pub page_table: PageTable,
    pub vmas: VmaManager,
    /// Minor page faults taken (first-touch frame assignment).
    pub minor_faults: u64,
    /// Translation epoch: bumped whenever an existing translation is
    /// torn down ([`Process::unmap_page`] / [`Process::unmap_vma`]).
    /// The coordinator's extent-translation cache keys on this, so any
    /// unmap implicitly invalidates every cached extent list for the
    /// process (DESIGN.md §5). Mapping *new* pages never changes the
    /// result of a previously successful translation and therefore
    /// does not bump the epoch.
    pub translation_epoch: u64,
}

/// A physically contiguous extent of a virtual range.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhysExtent {
    pub paddr: u64,
    pub len: u64,
}

impl Process {
    pub fn new(pid: Pid) -> Self {
        Self {
            pid,
            page_table: PageTable::new(),
            vmas: VmaManager::new(),
            minor_faults: 0,
            translation_epoch: 0,
        }
    }

    /// Tear down the translation containing `vaddr` and bump the
    /// translation epoch. Allocators must use this (not the raw page
    /// table) so cached extent translations are invalidated.
    pub fn unmap_page(&mut self, vaddr: u64) -> Result<Translation> {
        let t = self.page_table.unmap(vaddr)?;
        self.translation_epoch += 1;
        Ok(t)
    }

    /// Remove the VMA starting at `start` and bump the translation
    /// epoch (the range is no longer a legal operand).
    pub fn unmap_vma(&mut self, start: u64) -> Result<Vma> {
        let vma = self.vmas.unmap(start)?;
        self.translation_epoch += 1;
        Ok(vma)
    }

    /// Reserve a virtual range of `len` bytes (rounded to pages) with
    /// `align`, without populating translations (demand paging).
    pub fn mmap(&mut self, len: u64, align: u64, kind: VmaKind) -> Result<u64> {
        self.vmas.map(len, align, kind)
    }

    /// Map `npages` base frames starting at `vaddr`, pulling each
    /// frame from `frame_source` (simulates first-touch population;
    /// counts minor faults).
    pub fn populate_base(
        &mut self,
        vaddr: u64,
        npages: u64,
        mut frame_source: impl FnMut() -> Result<u64>,
    ) -> Result<()> {
        for i in 0..npages {
            let pa = frame_source().context("demand paging")? * PAGE_SIZE;
            self.page_table
                .map(vaddr + i * PAGE_SIZE, pa, PageKind::Base)?;
            self.minor_faults += 1;
        }
        Ok(())
    }

    /// Map a physically contiguous huge page at `vaddr`.
    pub fn map_huge(&mut self, vaddr: u64, paddr: u64) -> Result<()> {
        self.page_table.map(vaddr, paddr, PageKind::Huge)?;
        self.minor_faults += 1;
        Ok(())
    }

    /// Translate a virtual range into its physically contiguous
    /// extents (merging adjacent pages that happen to be contiguous).
    /// Fails if any page is unmapped.
    pub fn phys_extents(&self, vaddr: u64, len: u64) -> Result<Vec<PhysExtent>> {
        if len == 0 {
            return Ok(Vec::new());
        }
        let mut extents: Vec<PhysExtent> = Vec::new();
        let mut cur = vaddr;
        let end = vaddr + len;
        while cur < end {
            let t = match self.page_table.translate(cur) {
                Some(t) => t,
                None => bail!("unmapped address {cur:#x} in range"),
            };
            let page = match t.kind {
                PageKind::Base => PAGE_SIZE,
                PageKind::Huge => HUGE_PAGE_SIZE,
            };
            let page_end = super::align_down(cur, page) + page;
            let n = (page_end - cur).min(end - cur);
            match extents.last_mut() {
                Some(last) if last.paddr + last.len == t.paddr => {
                    last.len += n;
                }
                _ => extents.push(PhysExtent {
                    paddr: t.paddr,
                    len: n,
                }),
            }
            cur += n;
        }
        Ok(extents)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mmap_and_populate_roundtrip() {
        let mut p = Process::new(Pid(1));
        let va = p.mmap(3 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        let mut next = 100u64;
        p.populate_base(va, 3, || {
            next += 1;
            Ok(next - 1)
        })
        .unwrap();
        assert_eq!(p.minor_faults, 3);
        let t = p.page_table.translate(va + PAGE_SIZE).unwrap();
        assert_eq!(t.paddr, 101 * PAGE_SIZE);
    }

    #[test]
    fn phys_extents_merges_contiguous_frames() {
        let mut p = Process::new(Pid(1));
        let va = p.mmap(4 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        // frames 10,11,12 contiguous; 50 breaks the run
        let frames = [10u64, 11, 12, 50];
        let mut it = frames.iter().copied();
        p.populate_base(va, 4, || Ok(it.next().unwrap())).unwrap();
        let ext = p.phys_extents(va, 4 * PAGE_SIZE).unwrap();
        assert_eq!(
            ext,
            vec![
                PhysExtent {
                    paddr: 10 * PAGE_SIZE,
                    len: 3 * PAGE_SIZE
                },
                PhysExtent {
                    paddr: 50 * PAGE_SIZE,
                    len: PAGE_SIZE
                },
            ]
        );
    }

    #[test]
    fn phys_extents_partial_pages() {
        let mut p = Process::new(Pid(1));
        let va = p.mmap(2 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        let frames = [7u64, 9];
        let mut it = frames.iter().copied();
        p.populate_base(va, 2, || Ok(it.next().unwrap())).unwrap();
        // range starting mid-page
        let ext = p.phys_extents(va + 100, PAGE_SIZE).unwrap();
        assert_eq!(ext.len(), 2);
        assert_eq!(ext[0].paddr, 7 * PAGE_SIZE + 100);
        assert_eq!(ext[0].len, PAGE_SIZE - 100);
        assert_eq!(ext[1].len, 100);
    }

    #[test]
    fn phys_extents_fails_on_hole() {
        let mut p = Process::new(Pid(1));
        let va = p.mmap(2 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        p.populate_base(va, 1, || Ok(3)).unwrap();
        assert!(p.phys_extents(va, 2 * PAGE_SIZE).is_err());
    }

    #[test]
    fn unmap_wrappers_bump_translation_epoch() {
        let mut p = Process::new(Pid(1));
        let va = p.mmap(2 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
        p.populate_base(va, 2, || Ok(9)).unwrap();
        assert_eq!(p.translation_epoch, 0);
        p.unmap_page(va).unwrap();
        assert_eq!(p.translation_epoch, 1);
        p.unmap_page(va + PAGE_SIZE).unwrap();
        p.unmap_vma(va).unwrap();
        assert_eq!(p.translation_epoch, 3);
        assert!(p.unmap_page(va).is_err());
    }

    #[test]
    fn huge_mapping_single_extent() {
        let mut p = Process::new(Pid(2));
        let va = p
            .mmap(HUGE_PAGE_SIZE, HUGE_PAGE_SIZE, VmaKind::Huge)
            .unwrap();
        p.map_huge(va, 4 * HUGE_PAGE_SIZE).unwrap();
        let ext = p.phys_extents(va, HUGE_PAGE_SIZE).unwrap();
        assert_eq!(
            ext,
            vec![PhysExtent {
                paddr: 4 * HUGE_PAGE_SIZE,
                len: HUGE_PAGE_SIZE
            }]
        );
    }
}
