//! Boot-time huge-page pool (hugetlbfs semantics).
//!
//! Linux reserves huge pages at boot (`hugepages=N`); they are
//! physically contiguous 2 MiB blocks carved from the buddy allocator.
//! PUMA's `pim_preallocate` draws from this pool (paper §2: "a huge
//! pages pool for PUD memory objects (configured during boot time),
//! which guarantees that virtual addresses assigned to a PUD memory
//! object are contiguous in the physical address space").

use anyhow::{bail, Context, Result};

use super::buddy::{BuddyAllocator, Pfn};
use super::{HUGE_PAGE_ORDER, HUGE_PAGE_SIZE, PAGE_SIZE};

/// A reserved huge page: 2 MiB of physically contiguous memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HugePage {
    /// First frame of the page (aligned to 512 frames).
    pub pfn: Pfn,
}

impl HugePage {
    pub fn phys_addr(&self) -> u64 {
        self.pfn * PAGE_SIZE
    }

    pub fn len(&self) -> u64 {
        HUGE_PAGE_SIZE
    }
}

/// The boot-time pool.
#[derive(Debug)]
pub struct HugePagePool {
    free: Vec<HugePage>,
    pub reserved: usize,
}

impl HugePagePool {
    /// Reserve `count` huge pages from the buddy allocator. Done "at
    /// boot" — i.e. before churn fragments physical memory — or it may
    /// fail exactly the way hugetlb reservation fails on a busy system.
    pub fn reserve(buddy: &mut BuddyAllocator, count: usize) -> Result<Self> {
        let mut free = Vec::with_capacity(count);
        for i in 0..count {
            let pfn = buddy
                .alloc(HUGE_PAGE_ORDER)
                .with_context(|| format!("reserving huge page {i}/{count}"))?;
            free.push(HugePage { pfn });
        }
        // LIFO order is fine; keep deterministic (lowest first).
        free.sort_by_key(|h| h.pfn);
        free.reverse();
        Ok(Self {
            free,
            reserved: count,
        })
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Take one huge page from the pool.
    pub fn alloc(&mut self) -> Result<HugePage> {
        match self.free.pop() {
            Some(h) => Ok(h),
            None => bail!(
                "huge page pool exhausted ({} reserved)",
                self.reserved
            ),
        }
    }

    /// Return a huge page to the pool.
    pub fn release(&mut self, page: HugePage) {
        debug_assert!(
            !self.free.contains(&page),
            "double release of huge page {page:?}"
        );
        self.free.push(page);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_yields_aligned_contiguous_pages() {
        let mut buddy = BuddyAllocator::new(8192).unwrap();
        let pool = HugePagePool::reserve(&mut buddy, 4).unwrap();
        assert_eq!(pool.available(), 4);
        for h in &pool.free {
            assert_eq!(h.pfn % 512, 0, "huge page must be 2 MiB aligned");
        }
        assert_eq!(buddy.free_frames(), 8192 - 4 * 512);
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut buddy = BuddyAllocator::new(4096).unwrap();
        let mut pool = HugePagePool::reserve(&mut buddy, 2).unwrap();
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a.pfn, b.pfn);
        assert!(pool.alloc().is_err());
        pool.release(a);
        assert_eq!(pool.available(), 1);
        assert_eq!(pool.alloc().unwrap(), a);
    }

    #[test]
    fn reservation_fails_when_memory_exhausted() {
        let mut buddy = BuddyAllocator::new(1024).unwrap(); // 4 MiB
        assert!(HugePagePool::reserve(&mut buddy, 3).is_err());
    }

    #[test]
    fn phys_addr_math() {
        let h = HugePage { pfn: 512 };
        assert_eq!(h.phys_addr(), HUGE_PAGE_SIZE);
        assert_eq!(h.len(), HUGE_PAGE_SIZE);
    }
}
