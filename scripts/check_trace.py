#!/usr/bin/env python3
"""Validate a `puma trace --export` Chrome trace (trace.json) without
loading it into Perfetto: structural checks CI can run headlessly.

Checks (stdlib only, mirrors DESIGN.md §14's lane mapping):

* the file is JSON with a non-empty `traceEvents` array;
* every duration (`ph == "X"`) event carries numeric `ts`/`dur` >= 0;
* within each lane (pid, tid), events sorted by `ts` never overlap —
  waves serialize, so `ts[i] + dur[i] <= ts[i+1]` up to a small
  floating-point epsilon (timestamps are ns scaled to µs);
* PUD lanes (`process_name == "PUD banks (sim)"`) number at most
  --banks — one lane per *active* bank, never a phantom bank;
* the host-fallback process contributes at most one lane.

Usage:
  python3 scripts/check_trace.py out/trace/trace.json [--banks 16]
"""

import argparse
import json
import sys
from collections import defaultdict

# ts/dur are ns/1000; f64 formatting keeps ~15 significant digits, so
# adjacent waves can disagree by rounding dust, never by a real gap
EPSILON_US = 1e-3


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="path to trace.json")
    ap.add_argument(
        "--banks",
        type=int,
        default=16,
        help="geometry bank count upper-bounding the PUD lane count",
    )
    args = ap.parse_args()

    with open(args.trace) as f:
        doc = json.load(f)

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing or empty")

    # metadata: process/thread names
    process_names = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            process_names[ev["pid"]] = ev["args"]["name"]

    lanes = defaultdict(list)  # (pid, tid) -> [(ts, dur)]
    for ev in events:
        if ev.get("ph") != "X":
            continue
        ts, dur = ev.get("ts"), ev.get("dur")
        if not isinstance(ts, (int, float)) or not isinstance(
            dur, (int, float)
        ):
            fail(f"non-numeric ts/dur in {ev!r}")
        if ts < 0 or dur < 0:
            fail(f"negative ts/dur in {ev!r}")
        lanes[(ev["pid"], ev["tid"])].append((ts, dur))

    if not lanes:
        fail("no duration events")

    for (pid, tid), spans in lanes.items():
        spans.sort()
        for (t0, d0), (t1, _) in zip(spans, spans[1:]):
            if t0 + d0 > t1 + EPSILON_US:
                fail(
                    f"lane pid={pid} tid={tid}: event at {t0}us (+{d0}us) "
                    f"overlaps event at {t1}us"
                )

    pud_pids = {
        pid for pid, name in process_names.items() if name == "PUD banks (sim)"
    }
    host_pids = {
        pid
        for pid, name in process_names.items()
        if name == "host fallback (sim)"
    }
    if not pud_pids:
        fail("no 'PUD banks (sim)' process metadata")
    pud_lanes = {tid for (pid, tid) in lanes if pid in pud_pids}
    if len(pud_lanes) > args.banks:
        fail(
            f"{len(pud_lanes)} PUD lanes exceed the {args.banks}-bank "
            "geometry (one lane per active bank)"
        )
    host_lanes = {tid for (pid, tid) in lanes if pid in host_pids}
    if len(host_lanes) > 1:
        fail(f"{len(host_lanes)} host-fallback lanes (expected <= 1)")

    n_events = sum(len(s) for s in lanes.values())
    print(
        f"check_trace: OK — {n_events} span(s) across {len(pud_lanes)} PUD "
        f"lane(s) (<= {args.banks} banks) + {len(host_lanes)} host lane(s), "
        "monotonic and non-overlapping"
    )


if __name__ == "__main__":
    main()
