#!/usr/bin/env python3
"""Architecture lint: enforce the crate's layering invariants with
plain-text scans that run in CI before any compiler gets involved.

Three rules, each with the rationale it encodes:

1. pid-encapsulation — `Pid` is a coordinator-level capability; the
   multi-tenant front-end hands sessions out instead.  Raw `Pid`
   tokens are forbidden in `rust/src/workloads/serve.rs` and
   `rust/tests/prop_serve.rs`, and `src/serve/` must not re-export a
   session's pid beyond the crate (`pub pid` is only legal as
   `pub(crate) pid`).

2. plane-size math — every plane-byte computation must route through
   `layout::plane_bytes` (or the documented allowlist) so a future
   change to plane padding has exactly one home.  Open-coded
   `(x + 7) / 8`, `(x + 7) >> 3`, and `.div_ceil(8)` in `rust/src`
   are violations outside the allowlist; tests and benches may use
   the idiom freely when asserting against the layout layer.

3. deprecated-shims — the `#[deprecated]` compatibility shims on
   `System` may only be called from their defining file or from
   test files that opt in with a file-level `#![allow(deprecated)]`
   (the shim-pinning differential suites).  New call sites anywhere
   else must use the unified `Column`/batch API instead.

Exit status is the number of violations (0 = clean).  Each violation
prints as `file:line: [rule] message` so editors can jump to it.

Usage:
  python3 scripts/lint_arch.py [--root REPO_ROOT]
"""

import argparse
import os
import re
import sys

# Files where raw `Pid` must not appear at all (the serve layer's
# public seam: workloads and property tests speak Session, not Pid).
PID_FORBIDDEN = [
    "rust/src/workloads/serve.rs",
    "rust/tests/prop_serve.rs",
]

# Open-coded plane-size math allowed only here (see rule 2 docstring).
PLANE_MATH_ALLOWLIST = {
    "rust/src/pud/arith/layout.rs",  # plane_bytes lives here
    "rust/src/util/units.rs",  # size-string parsing, unrelated to planes
    "rust/src/analysis/verify.rs",  # truth-table lane sizing, not planes
}

PLANE_MATH_PATTERNS = [
    re.compile(r"\+\s*7\s*\)\s*/\s*8"),
    re.compile(r"\+\s*7\s*\)\s*>>\s*3"),
    re.compile(r"\.div_ceil\(8\)"),
]

SHIM_DEF_FILE = "rust/src/coordinator/system.rs"


def rel(path, root):
    return os.path.relpath(path, root).replace(os.sep, "/")


def rust_files(root, sub):
    out = []
    base = os.path.join(root, sub)
    for dirpath, _dirnames, filenames in os.walk(base):
        for name in sorted(filenames):
            if name.endswith(".rs"):
                out.append(os.path.join(dirpath, name))
    return sorted(out)


def read_lines(path):
    with open(path, encoding="utf-8") as f:
        return f.read().splitlines()


def strip_comment(line):
    """Drop // comments so doc references to shims don't count as calls."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def check_pid_encapsulation(root):
    violations = []
    for relpath in PID_FORBIDDEN:
        path = os.path.join(root, relpath)
        if not os.path.exists(path):
            continue
        for n, line in enumerate(read_lines(path), 1):
            if re.search(r"\bPid\b", strip_comment(line)):
                violations.append(
                    (relpath, n, "pid-encapsulation",
                     "raw `Pid` is forbidden here; use the Session API")
                )
    # src/serve/: a session's pid must stay crate-private.
    for path in rust_files(root, "rust/src/serve"):
        relpath = rel(path, root)
        for n, line in enumerate(read_lines(path), 1):
            code = strip_comment(line)
            if re.search(r"\bpub\s+pid\s*:", code):
                violations.append(
                    (relpath, n, "pid-encapsulation",
                     "`pub pid` leaks the coordinator Pid; "
                     "use `pub(crate) pid` at most")
                )
    return violations


def check_plane_math(root):
    violations = []
    for path in rust_files(root, "rust/src"):
        relpath = rel(path, root)
        if relpath in PLANE_MATH_ALLOWLIST:
            continue
        for n, line in enumerate(read_lines(path), 1):
            code = strip_comment(line)
            for pat in PLANE_MATH_PATTERNS:
                if pat.search(code):
                    violations.append(
                        (relpath, n, "plane-math",
                         "open-coded plane-size math; call "
                         "`layout::plane_bytes` instead")
                    )
                    break
    return violations


def deprecated_shim_names(root):
    """Parse fn names that carry a #[deprecated] attribute in the shim file."""
    path = os.path.join(root, SHIM_DEF_FILE)
    if not os.path.exists(path):
        return []
    lines = read_lines(path)
    names = []
    pending = False
    for line in lines:
        stripped = line.strip()
        if stripped.startswith("#[deprecated"):
            pending = True
            continue
        if pending:
            m = re.search(r"\bfn\s+([A-Za-z0-9_]+)", stripped)
            if m:
                names.append(m.group(1))
                pending = False
            elif stripped.startswith("#[") or stripped == "" or \
                    stripped.startswith("///") or stripped.startswith("//"):
                continue  # attributes/docs between #[deprecated] and fn
            else:
                pending = False
    return sorted(set(names))


def check_deprecated_shims(root):
    names = deprecated_shim_names(root)
    if not names:
        return []
    call_pat = re.compile(
        r"\.\s*(?:" + "|".join(re.escape(n) for n in names) + r")\s*\("
    )
    violations = []
    for sub in ("rust/src", "rust/tests", "rust/benches"):
        for path in rust_files(root, sub):
            relpath = rel(path, root)
            if relpath == SHIM_DEF_FILE:
                continue
            lines = read_lines(path)
            gated = any(
                line.strip().startswith("#![allow(deprecated)]")
                for line in lines
            )
            if gated:
                continue
            for n, line in enumerate(lines, 1):
                code = strip_comment(line)
                m = call_pat.search(code)
                if m:
                    violations.append(
                        (relpath, n, "deprecated-shims",
                         "call to a deprecated System shim "
                         f"({m.group(0).strip()}...) outside an "
                         "`#![allow(deprecated)]`-gated shim test; "
                         "use the unified Column API")
                    )
    return violations


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--root", default=os.path.join(os.path.dirname(__file__), ".."),
        help="repository root (default: the script's parent directory)",
    )
    args = ap.parse_args()
    root = os.path.abspath(args.root)

    violations = []
    violations += check_pid_encapsulation(root)
    violations += check_plane_math(root)
    violations += check_deprecated_shims(root)

    for relpath, line, rule, msg in violations:
        print(f"{relpath}:{line}: [{rule}] {msg}")
    if violations:
        print(f"lint_arch: {len(violations)} violation(s)")
        return min(len(violations), 125)
    shims = deprecated_shim_names(root)
    print(
        "lint_arch: clean "
        f"({len(shims)} deprecated shim(s) tracked, all call sites gated)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
