#!/usr/bin/env python3
"""Bench-regression gate: compare BENCH_runtime.json against the
committed BENCH_baseline.json and fail CI when the perf trajectory
regresses.

Gated metrics (all simulated-time, deterministic across runs):

* PUD-row fractions (batched mix, churn-with-compaction steady state,
  filter/puma compiled, analytics/puma worst cell): a relative drop of
  more than --pud-tolerance (default 2%) fails.
* Batched throughput (ops_per_s, simulated): a relative drop of more
  than --ops-tolerance (default 10%) fails.
* Host-boundary wall time (analytics host_ns_per_elem, flat and
  sharded — lower is better): a relative *rise* of more than
  --ops-tolerance (default 10%) fails.

A baseline value of null means "not yet seeded": the metric passes
with a warning and the refreshed baseline (--write-refreshed) fills
in the measured value, ready to be committed. Seeded entries keep
their committed (deliberately conservative) values in the refreshed
file — refresh fills gaps, it does not ratchet floors up.

Usage:
  python3 scripts/bench_gate.py \
      --current BENCH_runtime.json --baseline BENCH_baseline.json \
      [--write-refreshed BENCH_baseline_refreshed.json] \
      [--summary "$GITHUB_STEP_SUMMARY"]
"""

import argparse
import json
import sys


def extract(bench):
    """Pull the gated metrics out of BENCH_runtime.json."""
    analytics_puma = [
        c["pud_row_fraction"]
        for c in bench.get("analytics", {}).get("cells", [])
        if c.get("allocator") == "puma"
    ]
    sharded = bench.get("analytics_sharded", {})
    # The measured tracer overhead is frequently ~0 (min-of-N absorbs
    # it), and a relative gate around 0 is all noise — floor both the
    # current value and the seeded baseline at half the 5% hard budget
    # so the gate only reacts when the overhead becomes material.
    obs_overhead = bench.get("observability", {}).get(
        "obs_trace_overhead_frac"
    )
    if obs_overhead is not None:
        obs_overhead = max(obs_overhead, 0.025)
    # Same floor treatment for the static verifier's overhead: the
    # bench hard-asserts <10%, the gate reacts above half that budget.
    verify_overhead = bench.get("analysis", {}).get("verify_overhead_frac")
    if verify_overhead is not None:
        verify_overhead = max(verify_overhead, 0.05)
    return {
        "batched_pud_row_fraction": bench["batched"]["pud_row_fraction"],
        "batched_ops_per_s": bench["batched"]["ops_per_s"],
        "churn_on_steady_pud_fraction": bench["churn"]["on"][
            "steady_pud_fraction"
        ],
        "filter_puma_pud_row_fraction": bench["filter"]["puma"][
            "pud_row_fraction"
        ],
        "analytics_puma_min_pud_row_fraction": (
            min(analytics_puma) if analytics_puma else None
        ),
        # bank-sharded SIMD: the S=8 vs S=1 makespan win and the PUD-row
        # floor of the spread placement (null-seeded until committed)
        "analytics_sharded_speedup_s8": sharded.get("speedup_s8"),
        "analytics_sharded_puma_pud_row_fraction": sharded.get(
            "puma_pud_row_fraction"
        ),
        # host-boundary wall time per element (mean over PUMA cells):
        # blocked transpose + resident-column fetch + mask readback.
        # Lower is better; null-seeded until committed.
        "analytics_host_ns_per_elem": bench.get("analytics", {}).get(
            "host_ns_per_elem"
        ),
        "analytics_sharded_host_ns_per_elem": sharded.get("host_ns_per_elem"),
        # query engine (semi-join / group-by / top-k): the PUD-row floor
        # across every PUMA query cell and the mean host-boundary cost.
        # Null-seeded until committed.
        "queries_puma_min_pud_row_fraction": bench.get("queries", {}).get(
            "min_puma_pud_row_fraction"
        ),
        "queries_host_ns_per_elem": bench.get("queries", {}).get(
            "host_ns_per_elem"
        ),
        # observability: relative wall-clock cost of leaving the wave
        # tracer on for the batched pass (DESIGN.md §14 budgets <5%;
        # the bench asserts the hard cap, the gate tracks the drift).
        # Lower is better; null-seeded until committed.
        "obs_trace_overhead_frac": obs_overhead,
        # static verifier: relative wall-clock cost of VerifyLevel::Full
        # (dataflow + translation validation on every emitted stream)
        # over the analytics sweep. Lower is better; the bench asserts
        # the <10% hard cap, the gate tracks the drift. Null-seeded
        # until committed.
        "verify_overhead_frac": verify_overhead,
        # multi-tenant serving: the DRR schedule's p99 tenant completion
        # (simulated ns, lower is better — the fairness headline the
        # bench asserts strictly beats back-to-back) and the PUD-row
        # floor of the spread-anchored tenant placement. Null-seeded
        # until committed.
        "serve_p99_makespan": bench.get("serve", {}).get("serve_p99_makespan"),
        "serve_puma_pud_row_fraction": bench.get("serve", {}).get(
            "serve_puma_pud_row_fraction"
        ),
    }


# Metrics where a *rise* is the regression (wall-clock costs); everything
# else is higher-is-better.
LOWER_IS_BETTER = {
    "analytics_host_ns_per_elem",
    "analytics_sharded_host_ns_per_elem",
    "queries_host_ns_per_elem",
    "obs_trace_overhead_frac",
    "verify_overhead_frac",
    "serve_p99_makespan",
}


def tolerance_for(metric, args):
    if metric in LOWER_IS_BETTER or metric == "batched_ops_per_s":
        return args.ops_tolerance
    return args.pud_tolerance


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--write-refreshed")
    ap.add_argument("--summary")
    ap.add_argument("--pud-tolerance", type=float, default=0.02)
    ap.add_argument("--ops-tolerance", type=float, default=0.10)
    args = ap.parse_args()

    with open(args.current) as f:
        current = extract(json.load(f))
    with open(args.baseline) as f:
        baseline_file = json.load(f)

    rows = []
    failures = []
    refreshed = {
        "_comment": baseline_file.get("_comment", ""),
    }
    for metric, cur in current.items():
        base = baseline_file.get(metric)
        # fill unseeded entries with the measured value; keep committed
        # (conservative) floors as they are
        refreshed[metric] = cur if base is None else base
        if cur is None:
            rows.append((metric, base, cur, "-", "MISSING"))
            failures.append(f"{metric}: missing from the current bench run")
            continue
        if base is None:
            rows.append((metric, "(unseeded)", f"{cur:.6g}", "-", "SEEDED"))
            continue
        tol = tolerance_for(metric, args)
        delta = (cur - base) / base if base else 0.0
        if metric in LOWER_IS_BETTER:
            ceiling = base * (1.0 + tol)
            status = "OK" if cur <= ceiling else "FAIL"
            if status == "FAIL":
                failures.append(
                    f"{metric}: {cur:.6g} rose more than {tol:.0%} above "
                    f"baseline {base:.6g}"
                )
        else:
            floor = base * (1.0 - tol)
            status = "OK" if cur >= floor else "FAIL"
            if status == "FAIL":
                failures.append(
                    f"{metric}: {cur:.6g} dropped more than {tol:.0%} below "
                    f"baseline {base:.6g}"
                )
        rows.append(
            (metric, f"{base:.6g}", f"{cur:.6g}", f"{delta:+.2%}", status)
        )

    lines = [
        "### Bench gate — perf trajectory vs committed baseline",
        "",
        "| metric | baseline | current | delta | status |",
        "|---|---|---|---|---|",
    ]
    for metric, base, cur, delta, status in rows:
        lines.append(f"| `{metric}` | {base} | {cur} | {delta} | {status} |")
    if failures:
        lines.append("")
        lines.append("**Regressions:**")
        lines.extend(f"- {f}" for f in failures)
    report = "\n".join(lines) + "\n"
    print(report)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(report)

    if args.write_refreshed:
        with open(args.write_refreshed, "w") as f:
            json.dump(refreshed, f, indent=2)
            f.write("\n")
        print(f"refreshed baseline written to {args.write_refreshed}")

    if failures:
        print("bench gate FAILED", file=sys.stderr)
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
