//! Bitmap semi-join through the PUD query engine: build a
//! key-presence mask for `lineitem ⋉ customer` (every build-side key
//! becomes one cached `CmpEq`-const kernel, all OR-folded in a single
//! batch), AND a residual `quantity < T` predicate into it, then sum
//! the surviving rows' quantities with a masked in-DRAM reduction —
//! PUMA placement against malloc on the same compiled programs.
//!
//! ```bash
//! cargo run --release --example semi_join
//! ```

use puma::alloc::puma::FitPolicy;
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::util::units::fmt_ns;
use puma::workloads::microbench::AllocatorKind;
use puma::workloads::queries::{self, QueriesConfig};

fn main() -> anyhow::Result<()> {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    let cfg = QueriesConfig {
        rows: 16 * 1024,
        shards: 0, // flat placement only — sharded_sum covers sharding
        ..Default::default()
    };
    println!(
        "table: {} rows x {}-bit columns, {} build-side keys",
        cfg.rows, cfg.width, cfg.build_keys
    );

    let mut puma_frac = None;
    let mut malloc_frac = None;
    for kind in [
        AllocatorKind::Puma(FitPolicy::WorstFit),
        AllocatorKind::Malloc,
    ] {
        let rs = queries::run(scheme.clone(), &cfg, kind)?;
        let r = rs.iter().find(|r| r.shape == "semi_join").unwrap();
        println!("\n{}:", r.allocator);
        println!(
            "  semi-join     {} batch(es), {} wave(s), {} fresh compile(s)",
            r.batches, r.waves, r.compiles
        );
        println!(
            "  PUD rows      {:.1}% of the batched rows",
            r.pud_row_fraction() * 100.0
        );
        println!("  sim time      {} bank-parallel", fmt_ns(r.elapsed_ns));
        println!(
            "  result        {} surviving rows, SUM(quantity) = {} (verified)",
            r.matches, r.agg
        );
        match r.allocator {
            "puma" => puma_frac = Some(r.pud_row_fraction()),
            _ => malloc_frac = Some(r.pud_row_fraction()),
        }
    }

    // identical compiled kernels, identical table — only PUMA's
    // co-located bit-planes keep the join mask algebra in-DRAM
    let (p, m) = (puma_frac.unwrap(), malloc_frac.unwrap());
    assert!(p > 0.95, "PUMA placement must run in-DRAM (got {p})");
    assert!(p > m, "PUMA ({p}) must beat malloc ({m})");
    println!("\nsemi_join OK");
    Ok(())
}
