//! Predicate filter through the expression compiler: build
//! `(c0 & c1 & !c2) | ((c3 ^ c4) & c5) | ((c6 | c7) & !c2)` over eight
//! bitmap columns, compile it, and run it as ONE coordinator batch —
//! then do what callers had to do before the compiler (hand-issued
//! sequential ops with ad-hoc temps) and compare.
//!
//! ```bash
//! cargo run --release --example predicate_filter
//! ```

use puma::alloc::puma::FitPolicy;
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::util::units::fmt_ns;
use puma::workloads::filter::{self, predicate, FilterConfig};
use puma::workloads::microbench::AllocatorKind;

fn main() -> anyhow::Result<()> {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    let cfg = FilterConfig::default();
    let (expr, columns) = predicate(cfg.clauses);
    println!(
        "predicate ({} clauses over {columns} bitmap columns): {expr}",
        cfg.clauses
    );

    let mut puma_result = None;
    for kind in [
        AllocatorKind::Puma(FitPolicy::WorstFit),
        AllocatorKind::Malloc,
    ] {
        let r = filter::run(scheme.clone(), &cfg, kind)?;
        println!("\n{} ({} rows/column):", r.allocator, r.rows);
        println!(
            "  compiled      {} op(s), {} scratch row(s), {} CSE merge(s), \
             {} wave(s), 1 batch",
            r.compile.ops, r.compile.scratch_slots, r.compile.cse_hits, r.waves
        );
        println!(
            "  PUD rows      {:.1}% compiled vs {:.1}% hand-issued",
            r.compiled_pud_fraction * 100.0,
            r.hand_pud_fraction * 100.0
        );
        println!(
            "  sim time      {} compiled (bank-parallel) vs {} hand-issued \
             ({:.1}x)",
            fmt_ns(r.elapsed_ns),
            fmt_ns(r.hand_ns),
            r.speedup()
        );
        println!("  matches       {} rows (verified against the oracle)", r.matches);
        if r.allocator == "puma" {
            puma_result = Some(r);
        }
    }

    // the headline claim: same predicate, same machine — the compiler's
    // co-located scratch + single batch beats hand-issued ops under PUMA
    let r = puma_result.expect("the PUMA cell ran above");
    assert!(r.compiled_pud_fraction > r.hand_pud_fraction);
    assert!(r.speedup() > 1.0);
    println!("\npredicate_filter OK");
    Ok(())
}
