//! Bitmap-index queries — the database workload motivating Ambit-class
//! PUD — under PUMA vs malloc placement.
//!
//! Builds a bitmap index over a 4M-row table (one bitmap per attribute
//! value), runs a batch of conjunctive queries, and compares the two
//! allocators: PUMA's placement keeps the ANDs in-DRAM, malloc's sends
//! every one to the CPU.
//!
//! ```bash
//! cargo run --release --example bitmap_index
//! ```

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::traits::Allocator;
use puma::config;
use puma::coordinator::system::{System, SystemConfig};
use puma::util::units::fmt_ns;
use puma::workloads::bitmap_index::BitmapIndex;

const TABLE_ROWS: u64 = 4 << 20; // 4M rows -> 512 KiB bitmaps
const VALUES: [&str; 6] = ["red", "blue", "large", "small", "recent", "archived"];
const QUERIES: [&[usize]; 4] = [&[0, 2], &[1, 3, 4], &[0, 2, 4], &[1, 5]];

fn run(label: &str, sys: &mut System, alloc: &mut dyn Allocator) -> anyhow::Result<f64> {
    let pid = sys.spawn();
    let idx = BitmapIndex::build(sys, alloc, pid, &VALUES, TABLE_ROWS, 0.25, 1234)?;
    let mut total_ns = 0.0;
    for (qi, q) in QUERIES.iter().enumerate() {
        let (ns, count) = idx.query_and(sys, q)?;
        let want = idx.expected_count(q);
        assert_eq!(count, want, "query {qi} count mismatch");
        total_ns += ns;
        println!("  [{label}] query {qi} ({} terms): {count:>8} rows in {}",
            q.len(), fmt_ns(ns));
    }
    println!(
        "  [{label}] PUD fraction {:.0}%, total {}",
        sys.coord.stats.pud_row_fraction() * 100.0,
        fmt_ns(total_ns)
    );
    Ok(total_ns)
}

fn boot() -> anyhow::Result<System> {
    System::boot(SystemConfig {
        huge_pages: 64,
        artifacts: config::default_artifacts(),
        ..Default::default()
    })
}

fn main() -> anyhow::Result<()> {
    println!("bitmap index over {} rows, {} bitmaps", TABLE_ROWS, VALUES.len());

    println!("PUMA placement:");
    let mut sys = boot()?;
    let mut puma = PumaAlloc::new(
        sys.os.scheme.geometry.row_bytes as u64,
        FitPolicy::WorstFit,
    );
    puma.pim_preallocate(&mut sys.os, 16)?;
    let puma_ns = run("puma", &mut sys, &mut puma)?;

    println!("malloc placement:");
    let mut sys = boot()?;
    let mut malloc = MallocSim::new();
    let malloc_ns = run("malloc", &mut sys, &mut malloc)?;

    println!(
        "\nspeedup (simulated): {:.1}x — queries run in-DRAM under PUMA",
        malloc_ns / puma_ns
    );
    Ok(())
}
