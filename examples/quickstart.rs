//! Quickstart: boot the machine, allocate three PUD-placed arrays with
//! PUMA's three-call API, run one in-DRAM AND, and inspect the stats.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::config;
use puma::coordinator::system::{System, SystemConfig};
use puma::pud::isa::{BulkRequest, PudOp};
use puma::util::units::{fmt_bytes, fmt_ns};

fn main() -> anyhow::Result<()> {
    // 1. Boot an 8 GiB machine (Linux-like buddy allocator, hugetlb
    //    pool, churned free lists) with the default row-major DRAM
    //    interleaving. Loading the AOT artifacts gives the real
    //    XLA-backed CPU fallback; scalar fallback works too.
    let mut sys = System::boot(SystemConfig {
        huge_pages: 64,
        artifacts: config::default_artifacts(),
        ..Default::default()
    })?;
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;

    // 2. pim_preallocate: dedicate huge pages to the PUD region pool.
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 16)?;
    println!("PUD pool: {} row-regions", puma.free_regions());

    // 3. pim_alloc + pim_alloc_align: the first operand places
    //    worst-fit; the others co-locate with it subarray-by-subarray.
    let len = 64 * row; // 512 KiB per operand
    let a = sys.alloc(&mut puma, pid, len)?;
    let b = sys.alloc_align(&mut puma, pid, len, a)?;
    let c = sys.alloc_align(&mut puma, pid, len, a)?;
    println!("operands: {} each at {a:#x}, {b:#x}, {c:#x}", fmt_bytes(len));

    // 4. Fill the sources and run C = A AND B.
    let va: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
    let vb: Vec<u8> = (0..len).map(|i| ((i * 7) % 253) as u8).collect();
    sys.write_virt(pid, a, &va)?;
    sys.write_virt(pid, b, &vb)?;
    let ns = sys.submit(pid, &BulkRequest::new(PudOp::And, c, vec![a, b], len))?;

    // 5. Verify and report.
    let got = sys.read_virt(pid, c, len)?;
    let want: Vec<u8> = va.iter().zip(&vb).map(|(x, y)| x & y).collect();
    assert_eq!(got, want, "in-DRAM AND must match the host oracle");

    let st = &sys.coord.stats;
    println!("executed in   {}", fmt_ns(ns));
    println!(
        "PUD rows      {} / {} ({:.0}%)",
        st.pud_rows,
        st.pud_rows + st.fallback_rows,
        st.pud_row_fraction() * 100.0
    );
    println!("AAPs issued   {}", sys.coord.engine.device.counters.aaps);
    println!("TRAs issued   {}", sys.coord.engine.device.counters.tras);
    println!("quickstart OK");
    Ok(())
}
