//! MIMDRAM-style bank-sharded SIMD: the filter-then-sum aggregate of
//! `column_sum`, with the column partitioned into bank-disjoint shards
//! executed in lockstep by the hazard-wave scheduler.
//!
//! S = 1 is the fully co-located single-subarray layout — PUD-legal,
//! but serialized on one bank's command timeline. Sharding spreads the
//! *data* across banks (PUMA's placement-spread path cycles shard
//! anchors over bank ids), so the same compiled kernel — compiled once
//! via the `(op, width)` program cache, emitted once per shard, one
//! `submit_batch` — finishes in a fraction of the makespan, with
//! bit-identical results.
//!
//! ```bash
//! cargo run --release --example sharded_sum
//! ```

use puma::alloc::puma::FitPolicy;
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::util::units::fmt_ns;
use puma::workloads::analytics::{self, threshold, ShardedConfig};
use puma::workloads::microbench::AllocatorKind;

fn main() -> anyhow::Result<()> {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB, 4 banks
    let cfg = ShardedConfig {
        elems: 256 * 1024, // 4 DRAM rows per unsharded bit-plane
        widths: vec![8],
        shards: vec![1, 2, 4],
        huge_pages: 16,
        puma_pages: 8,
        ..Default::default()
    };
    println!(
        "column: {} x {}-bit values, predicate v < {}, shard counts {:?}",
        cfg.elems,
        cfg.widths[0],
        threshold(cfg.widths[0], cfg.threshold_frac),
        cfg.shards
    );

    let mut puma_cells = Vec::new();
    for kind in [
        AllocatorKind::Puma(FitPolicy::WorstFit),
        AllocatorKind::Malloc,
    ] {
        let rs = analytics::run_sharded(scheme.clone(), &cfg, kind)?;
        println!("\n{}:", rs[0].allocator);
        for r in &rs {
            println!(
                "  S={:<2} {} wave(s), {:>3.0}% in-DRAM, elapsed {:>10} \
                 (matches {}, sum {})",
                r.shard_count,
                r.waves,
                r.pud_row_fraction() * 100.0,
                fmt_ns(r.elapsed_ns),
                r.matches,
                r.sum
            );
        }
        if rs[0].allocator == "puma" {
            puma_cells = rs;
        }
    }

    // the headline claim: identical compiled kernels, identical data,
    // identical results — spreading shards across banks shrinks the
    // batch makespan near-linearly in min(S, banks)
    let s1 = puma_cells.iter().find(|r| r.shards == 1).unwrap();
    let best = puma_cells
        .iter()
        .min_by(|a, b| a.elapsed_ns.total_cmp(&b.elapsed_ns))
        .unwrap();
    assert!(s1.pud_row_fraction() > 0.95, "PUMA placement runs in-DRAM");
    assert!(
        best.shards > 1 && best.elapsed_ns < s1.elapsed_ns,
        "sharding must beat the single-subarray layout ({} vs {})",
        best.elapsed_ns,
        s1.elapsed_ns
    );
    assert!(puma_cells.iter().all(|r| r.sum == s1.sum));
    println!(
        "\nbest: S={} at {:.2}x over S=1 — sharded_sum OK",
        best.shard_count,
        s1.elapsed_ns / best.elapsed_ns
    );
    Ok(())
}
