//! Filter-then-sum through the bit-serial vertical-arithmetic layer:
//! transpose an 8-bit column into bit-plane rows, compile
//! `SELECT SUM(v) WHERE v < 128` as a constant-folded compare plus a
//! masked-plane batch, and compare PUMA placement (in-DRAM) against
//! malloc (CPU fallback) on the same compiled programs.
//!
//! ```bash
//! cargo run --release --example column_sum
//! ```

use puma::alloc::puma::FitPolicy;
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::util::units::fmt_ns;
use puma::workloads::analytics::{self, threshold, AnalyticsConfig};
use puma::workloads::microbench::AllocatorKind;

fn main() -> anyhow::Result<()> {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    let cfg = AnalyticsConfig {
        widths: vec![8],
        ..Default::default()
    };
    println!(
        "column: {} x {}-bit values, predicate v < {}",
        cfg.elems,
        cfg.widths[0],
        threshold(cfg.widths[0], cfg.threshold_frac)
    );

    let mut puma_frac = None;
    let mut malloc_frac = None;
    for kind in [
        AllocatorKind::Puma(FitPolicy::WorstFit),
        AllocatorKind::Malloc,
    ] {
        let rs = analytics::run(scheme.clone(), &cfg, kind)?;
        let r = &rs[0];
        println!("\n{}:", r.allocator);
        println!(
            "  compare       {} op(s) after folding ({} fold(s)), \
             {} wave(s), 1 batch",
            r.compile.ops, r.compile.folds, r.waves
        );
        println!(
            "  PUD rows      {:.1}% of the compiled batches",
            r.pud_row_fraction() * 100.0
        );
        println!(
            "  sim time      {} bank-parallel ({:.2} AAPs/elem in-DRAM)",
            fmt_ns(r.elapsed_ns),
            r.aaps_per_elem
        );
        println!(
            "  result        {} matching rows, sum {} (verified)",
            r.matches, r.sum
        );
        match r.allocator {
            "puma" => puma_frac = Some(r.pud_row_fraction()),
            _ => malloc_frac = Some(r.pud_row_fraction()),
        }
    }

    // the headline claim: identical compiled kernels, identical data —
    // only PUMA's hint-aligned bit-planes keep the pipeline in-DRAM
    let (p, m) = (puma_frac.unwrap(), malloc_frac.unwrap());
    assert!(p > 0.95, "PUMA placement must run in-DRAM (got {p})");
    assert!(p > m, "PUMA ({p}) must beat malloc ({m})");
    println!("\ncolumn_sum OK");
    Ok(())
}
