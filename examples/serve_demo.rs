//! Serving front-end quickstart: open tenant sessions on a
//! [`puma::serve::Gateway`], submit bulk work through admission
//! control, and drain it with the DRR fairness scheduler — then run
//! the full twin-gateway fairness study from
//! [`puma::workloads::serve`] (`puma serve` is the configurable CLI
//! version).
//!
//! Note what the tenant code never sees: a `Pid`. Sessions own the
//! process handle; everything goes through `SessionId`.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::request::AllocRequest;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::pud::isa::{BulkRequest, PudOp};
use puma::report;
use puma::serve::{Gateway, GatewayConfig, SessionConfig};
use puma::workloads::microbench::AllocatorKind;
use puma::workloads::serve::{self, ServeConfig};

fn scheme() -> InterleaveScheme {
    // 64 MiB — small enough to serve in a second
    InterleaveScheme::row_major(DramGeometry::small())
}

fn main() -> anyhow::Result<()> {
    // --- 1. the Session API, by hand -------------------------------
    let sys = System::boot(SystemConfig {
        scheme: scheme(),
        huge_pages: 8,
        churn_rounds: 500,
        seed: 7,
        ..Default::default()
    })?;
    let mut gw = Gateway::new(
        sys,
        Box::new(MallocSim::new()),
        GatewayConfig { quantum: 8 },
    );
    let id = gw.open(SessionConfig::named("demo"));
    let len = 16 * 1024u64;
    let (a, b, c) = gw.with_session(id, |sess, sys, alloc| {
        let a = sess.alloc(sys, alloc, AllocRequest::bytes(len))?;
        let b = sess.alloc(sys, alloc, AllocRequest::bytes(len).align_with(a))?;
        let c = sess.alloc(sys, alloc, AllocRequest::bytes(len).align_with(a))?;
        sess.write(sys, a, &vec![0xAAu8; len as usize])?;
        sess.write(sys, b, &vec![0x0Fu8; len as usize])?;
        Ok((a, b, c))
    })?;
    let outcome =
        gw.submit(id, BulkRequest::new(PudOp::And, c, vec![a, b], len))?;
    println!("submit -> {outcome:?}");
    let rounds = gw.drain()?;
    let got = gw.with_session(id, |sess, sys, _| sess.read(sys, c, len))?;
    assert!(got.iter().all(|&x| x == (0xAA & 0x0F)));
    println!(
        "drained in {rounds} round(s); c = a AND b verified; clock {:.0} ns",
        gw.clock_ns()
    );
    gw.close(id)?;

    // --- 2. the fairness study -------------------------------------
    let cfg = ServeConfig {
        tenants: 8,
        ops_per_tenant: 8,
        buf_bytes: 16 * 1024,
        backpressure: 4,
        churn_rounds: 500,
        ..Default::default()
    };
    println!(
        "\nserving {} tenants x {} ops under DRR vs back-to-back...",
        cfg.tenants, cfg.ops_per_tenant
    );
    let results = serve::sweep(
        &scheme(),
        &cfg,
        &[
            AllocatorKind::Malloc,
            AllocatorKind::Puma(puma::alloc::puma::FitPolicy::WorstFit),
        ],
    )?;
    println!("{}", report::serve(&results, None)?);
    for r in &results {
        assert!(r.identical, "{}: schedules diverged", r.allocator);
    }
    let puma_run = results
        .iter()
        .find(|r| r.allocator == "puma")
        .expect("puma run present");
    assert!(
        puma_run.drr_p99_ns < puma_run.b2b_p99_ns,
        "DRR must beat back-to-back at the tail on PUMA placement"
    );
    println!("serve_demo OK");
    Ok(())
}
