//! Database scan through the full three-layer stack: the fused
//! popcount(A AND B) bitmap-scan kernel (L1 Pallas -> L2 JAX -> AOT
//! HLO) executed from rust via PJRT, next to the coordinator's
//! PUD/fallback dispatch for the same query.
//!
//! This example REQUIRES the artifacts (`make artifacts`) because the
//! fused scan only exists as an XLA executable.
//!
//! ```bash
//! make artifacts && cargo run --release --example database_scan
//! ```

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::config;
use puma::coordinator::system::{System, SystemConfig};
use puma::runtime::{XlaRuntime, ROW_BYTES};
use puma::util::rng::Pcg64;
use puma::util::units::fmt_ns;
use puma::workloads::bitmap_index::BitmapIndex;

fn main() -> anyhow::Result<()> {
    let Some(artifacts) = config::default_artifacts() else {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    };

    // --- Path 1: the fused bitmapscan XLA kernel, straight from rust.
    let mut rt = XlaRuntime::load(&artifacts)?;
    let rows = 96u32; // 96 DRAM rows = 768 KiB per bitmap
    let n = rows as usize * ROW_BYTES;
    let mut rng = Pcg64::new(42);
    let mut a = vec![0u8; n];
    let mut b = vec![0u8; n];
    rng.fill_bytes(&mut a);
    rng.fill_bytes(&mut b);
    let t0 = std::time::Instant::now();
    let matches = rt.bitmap_scan(rows, &a, &b)?;
    let wall = t0.elapsed();
    let want: i64 = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x & y).count_ones() as i64)
        .sum();
    assert_eq!(matches, want, "fused scan must match host popcount");
    println!(
        "fused XLA bitmap scan: {} matching bits over {} ({} dispatches, {:?} wall)",
        matches,
        puma::util::units::fmt_bytes(n as u64),
        rt.dispatches,
        wall
    );

    // --- Path 2: the same query through the coordinator (AND in-DRAM
    //     under PUMA placement, count on readback).
    let mut sys = System::boot(SystemConfig {
        huge_pages: 64,
        artifacts: Some(artifacts),
        ..Default::default()
    })?;
    let pid = sys.spawn();
    let mut puma = PumaAlloc::new(
        sys.os.scheme.geometry.row_bytes as u64,
        FitPolicy::WorstFit,
    );
    puma.pim_preallocate(&mut sys.os, 16)?;
    let idx = BitmapIndex::build(
        &mut sys,
        &mut puma,
        pid,
        &["color=red", "size=large"],
        (n * 8) as u64,
        0.5,
        42,
    )?;
    let (ns, count) = idx.query_and(&mut sys, &[0, 1])?;
    assert_eq!(count, idx.expected_count(&[0, 1]));
    println!(
        "coordinator scan: {count} rows in {} simulated ({:.0}% in-DRAM)",
        fmt_ns(ns),
        sys.coord.stats.pud_row_fraction() * 100.0
    );
    println!("database_scan OK");
    Ok(())
}
