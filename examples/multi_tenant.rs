//! Multi-tenant stress: several processes allocate, compute, and free
//! concurrently-interleaved PUD working sets while the machine ages —
//! first with the paper's alloc-time-only lifecycle, then with the
//! reclamation + RowClone-compaction lifecycle on top.
//!
//! The heavy lifting lives in [`puma::workloads::churn`]; this example
//! runs the comparison on the default machine and prints the curves
//! (`puma churn` is the configurable CLI version).
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::report;
use puma::workloads::churn::{self, ChurnConfig};

fn scheme() -> InterleaveScheme {
    // 64 MiB — small enough to churn hard in a second
    InterleaveScheme::row_major(DramGeometry::small())
}

fn main() -> anyhow::Result<()> {
    let tenants = 4;
    let cfg = ChurnConfig {
        tenants,
        ..Default::default()
    };
    println!(
        "churning {} tenants x {} epochs (pool {} huge pages)...",
        cfg.tenants, cfg.epochs, cfg.puma_pages
    );

    let off = churn::run(scheme(), &cfg)?;
    let on = churn::run(
        scheme(),
        &ChurnConfig {
            compact: true,
            ..cfg
        },
    )?;

    println!("{}", report::churn(&off, Some(&on), None)?);

    assert!(
        on.steady_state_pud_fraction >= off.steady_state_pud_fraction,
        "compaction must not lose in-DRAM coverage"
    );
    assert!(
        on.pages_returned >= 1,
        "compaction must return at least one huge page to the boot pool"
    );
    println!("multi_tenant OK");
    Ok(())
}
