//! Multi-tenant stress: several processes allocate, compute, and free
//! concurrently-interleaved PUD working sets while the machine ages.
//!
//! Exercises the part of PUMA the micro-benchmarks do not: the region
//! pool filling up, hint co-location degrading under pressure, and
//! frees recycling regions across tenants. Reports per-tenant PUD
//! fractions and pool occupancy over time.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::traits::Allocator;
use puma::coordinator::system::{System, SystemConfig};
use puma::util::units::fmt_ns;
use puma::workloads::trace::Trace;

const TENANTS: usize = 4;

fn main() -> anyhow::Result<()> {
    let mut sys = System::boot(SystemConfig {
        huge_pages: 48,
        churn_rounds: 30_000,
        ..Default::default()
    })?;
    let row = sys.os.scheme.geometry.row_bytes as u64;

    // one shared kernel-side PUMA instance, as in the real design:
    // the module is system-wide, allocations are per-process
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 32)?;
    println!(
        "boot: {} regions in the PUD pool across {} subarrays",
        puma.free_regions(),
        sys.os.scheme.geometry.total_subarrays()
    );

    let mut total_ns = 0.0;
    for tenant in 0..TENANTS {
        let pid = sys.spawn();
        // each tenant runs a different deterministic trace
        let trace = Trace::generate(
            0xBEEF + tenant as u64,
            8,              // operand groups
            (16 + 16 * tenant as u64) * row, // growing working sets
            4,              // ops per group
        );
        let before_rows = sys.coord.stats.pud_rows + sys.coord.stats.fallback_rows;
        let before_pud = sys.coord.stats.pud_rows;
        let ns = trace.replay(&mut sys, &mut puma, pid)?;
        total_ns += ns;
        let rows = (sys.coord.stats.pud_rows + sys.coord.stats.fallback_rows)
            - before_rows;
        let pud = sys.coord.stats.pud_rows - before_pud;
        println!(
            "tenant {tenant}: {} ops rows, {:.0}% in-DRAM, {} free regions left, {}",
            rows,
            100.0 * pud as f64 / rows.max(1) as f64,
            puma.free_regions(),
            fmt_ns(ns)
        );
    }

    let st = puma.stats();
    println!(
        "\nco-location: {} hint-aligned regions placed, {} missed to worst-fit",
        st.hint_colocated, st.hint_missed
    );
    println!(
        "fleet PUD fraction {:.0}%, total simulated {}",
        sys.coord.stats.pud_row_fraction() * 100.0,
        fmt_ns(total_ns)
    );
    assert!(
        sys.coord.stats.pud_row_fraction() > 0.7,
        "PUMA should keep most rows in-DRAM even under multi-tenant churn"
    );
    println!("multi_tenant OK");
    Ok(())
}
