//! END-TO-END DRIVER: reproduce the paper's Figure 2 on the full
//! three-layer stack.
//!
//! For every micro-benchmark (`*-zero`, `*-copy`, `*-aand`) and every
//! allocation size in the paper's sweep (2000 bits ... 6 Mb), this
//! boots a fresh 8 GiB machine, allocates operands with PUMA
//! (pim_alloc / pim_alloc_align) and with malloc, dispatches the bulk
//! operations through the coordinator — in-DRAM when legal, through
//! the AOT-compiled XLA kernels otherwise — and reports the speedup
//! series exactly like the paper's figure. Results land in
//! `out/figure2.csv` and are summarized in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_fig2
//! ```
//!
//! Runtime is a few minutes with XLA (set PUMA_E2E_FAST=1 for a quick
//! subset).

use puma::alloc::puma::FitPolicy;
use puma::config;
use puma::report;
use puma::workloads::microbench::{AllocatorKind, Micro};
use puma::workloads::sweep::{self, SweepConfig};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("PUMA_E2E_FAST").is_ok();
    let mut cfg = SweepConfig {
        artifacts: config::default_artifacts(),
        ..Default::default()
    };
    if cfg.artifacts.is_none() {
        eprintln!("note: artifacts/ missing — falling back to scalar CPU path");
    }
    if fast {
        cfg.sizes = vec![250, 64 << 10, 768 << 10];
    }

    let mut series = Vec::new();
    for micro in Micro::ALL {
        eprintln!("[e2e] sweeping {}-micro ({} sizes)...", micro.name(), cfg.sizes.len());
        let cells = sweep::run_micro_sweep(
            &cfg,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            micro,
        )?;
        for c in &cells {
            eprintln!(
                "[e2e]   {}  size {:>8}  speedup {:>6.2}x  pud {:>4.0}%  xla {} dispatches",
                micro.name(),
                c.result.size,
                c.speedup(),
                c.result.pud_fraction() * 100.0,
                c.result.coord.xla_dispatches,
            );
        }
        series.push((micro, cells));
    }

    let out = std::path::Path::new("out");
    println!("{}", report::figure2(&series, Some(out))?);

    // headline checks (the paper's two observations)
    for (micro, cells) in &series {
        let first = cells.first().unwrap().speedup();
        let last = cells.last().unwrap().speedup();
        assert!(
            last >= 1.0,
            "{}: PUMA should win at the top size (got {last:.2}x)",
            micro.name()
        );
        assert!(
            last > first * 0.8,
            "{}: speedup should not collapse with size ({first:.2}x -> {last:.2}x)",
            micro.name()
        );
    }
    println!("e2e_fig2 OK — raw series in out/figure2.csv");
    Ok(())
}
