"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in ``bitwise.py`` has a one-line reference here; pytest
(``python/tests/test_kernel.py``) asserts bit-exact agreement across a
hypothesis sweep of shapes and dtypes. This file is the single source
of truth for functional semantics — the rust PUD substrate's unit
tests encode the same identities independently.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_and(x, y):
    return x & y


def ref_or(x, y):
    return x | y


def ref_xor(x, y):
    return x ^ y


def ref_not(x):
    return ~x


def ref_copy(x):
    return x


def ref_zero(rows: int, lanes: int, dtype=jnp.int32):
    return jnp.zeros((rows, lanes), dtype)


def ref_maj3(a, b, c):
    """Bitwise majority — the Ambit triple-row-activation primitive."""
    return (a & b) | (b & c) | (c & a)


def ref_popcount_i32(v):
    """Per-lane popcount of an int32/uint32 array (SWAR, matches kernel)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def ref_and_popcount(x, y):
    """popcount(x & y) summed per row -> (rows, 1) int32."""
    return jnp.sum(ref_popcount_i32(x & y), axis=1, keepdims=True,
                   dtype=jnp.int32)


#: name -> (reference fn over arrays, arity) — mirrors bitwise.OPS.
REF_OPS = {
    "and": (ref_and, 2),
    "or": (ref_or, 2),
    "xor": (ref_xor, 2),
    "not": (ref_not, 1),
    "copy": (ref_copy, 1),
    "maj3": (ref_maj3, 3),
    "andpop": (ref_and_popcount, 2),
}
