"""L1 — Pallas kernels for the PUD operation set.

These kernels are the *CPU-fallback* implementations of exactly the
operations the modeled PUD substrate (Ambit + RowClone) can execute
in-DRAM:

  ===========  =========================  ==========================
  kernel       PUD analogue               mechanism modeled
  -----------  -------------------------  --------------------------
  copy         RowClone FPM               ACT src -> ACT dst (AAP)
  zero         RowClone zero-init         AAP from reserved zero row
  and_ / or_   Ambit triple-row act.      maj(A, B, C=0/1)
  not_         Ambit dual-contact cell    bitline inversion
  xor_         Ambit composite            3x TRA + 2x NOT sequence
  maj3         Ambit TRA primitive        maj(A, B, C) on bitlines
  and_popcount bitmap-scan fused op       TRA + host reduce
  ===========  =========================  ==========================

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
substrate operates on whole DRAM rows (8 KiB = 65536 bitlines at once).
We mirror that structure: arrays are shaped ``(rows, LANES)`` with
``LANES = 2048`` int32 lanes == one 8 KiB DRAM row per grid step, and
each kernel tiles with ``BlockSpec((block_rows, LANES))`` so the
HBM->VMEM block schedule corresponds to ACTIVATE(row)->row-buffer.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness (vs ``ref.py``) is
the signal we need — PUD timing is analytic, in the rust simulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One DRAM row = 8 KiB = 2048 x int32 lanes. Keep in sync with
# rust/src/dram/geometry.rs::ROW_BYTES.
LANES = 2048

# Rows per VMEM block. 8 rows x 8 KiB = 64 KiB per operand block —
# comfortably inside a ~16 MiB VMEM budget even for 3-operand kernels,
# wide enough to amortize the grid loop. See EXPERIMENTS.md §Perf for
# the block-shape sweep that picked this value.
DEFAULT_BLOCK_ROWS = 8


def _block_rows(rows: int, block_rows: int | None) -> int:
    """Largest divisor of ``rows`` not exceeding the requested block."""
    b = min(block_rows or DEFAULT_BLOCK_ROWS, rows)
    while rows % b:
        b -= 1
    return b


def _row_spec(block_rows: int, lanes: int) -> pl.BlockSpec:
    return pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))


def _pallas_rowwise(kernel, n_in: int, rows: int, lanes: int,
                    block_rows: int | None, dtype=jnp.int32,
                    out_lanes: int | None = None):
    """Common wrapper: row-tiled elementwise kernel over (rows, lanes)."""
    b = _block_rows(rows, block_rows)
    out_lanes = lanes if out_lanes is None else out_lanes
    return pl.pallas_call(
        kernel,
        grid=(rows // b,),
        in_specs=[_row_spec(b, lanes)] * n_in,
        out_specs=_row_spec(b, out_lanes),
        out_shape=jax.ShapeDtypeStruct((rows, out_lanes), dtype),
        interpret=True,
    )


# ---------------------------------------------------------------- kernels

def _and_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] & y_ref[...]


def _or_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] | y_ref[...]


def _xor_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] ^ y_ref[...]


def _not_kernel(x_ref, o_ref):
    o_ref[...] = ~x_ref[...]


def _copy_kernel(x_ref, o_ref):
    # RowClone-FPM analogue: the block transits VMEM the way a row
    # transits the row buffer.
    o_ref[...] = x_ref[...]


def _zero_kernel(o_ref):
    # RowClone zero-init: copy from the reserved all-zeros row.
    o_ref[...] = jnp.zeros_like(o_ref)


def _maj3_kernel(a_ref, b_ref, c_ref, o_ref):
    # Ambit TRA primitive: bitline majority of three simultaneously
    # activated rows.
    a, b, c = a_ref[...], b_ref[...], c_ref[...]
    o_ref[...] = (a & b) | (b & c) | (c & a)


def _popcount_i32(v):
    """SWAR popcount per int32 lane (Hacker's Delight 5-2)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _and_popcount_kernel(x_ref, y_ref, o_ref):
    # Fused bitmap-scan op: popcount(A AND B) reduced per row-block.
    # o_ref is (block_rows, 1): one partial count per row.
    v = x_ref[...] & y_ref[...]
    o_ref[...] = jnp.sum(_popcount_i32(v), axis=1, keepdims=True)


# ------------------------------------------------------------ public API
#
# Each op_* builds the row-tiled pallas computation for a concrete
# (rows, lanes, dtype) shape; model.py composes these into the L2 graph.

def op_and(rows: int, lanes: int = LANES, block_rows: int | None = None,
           dtype=jnp.int32):
    return _pallas_rowwise(_and_kernel, 2, rows, lanes, block_rows, dtype)


def op_or(rows: int, lanes: int = LANES, block_rows: int | None = None,
          dtype=jnp.int32):
    return _pallas_rowwise(_or_kernel, 2, rows, lanes, block_rows, dtype)


def op_xor(rows: int, lanes: int = LANES, block_rows: int | None = None,
           dtype=jnp.int32):
    return _pallas_rowwise(_xor_kernel, 2, rows, lanes, block_rows, dtype)


def op_not(rows: int, lanes: int = LANES, block_rows: int | None = None,
           dtype=jnp.int32):
    return _pallas_rowwise(_not_kernel, 1, rows, lanes, block_rows, dtype)


def op_copy(rows: int, lanes: int = LANES, block_rows: int | None = None,
            dtype=jnp.int32):
    return _pallas_rowwise(_copy_kernel, 1, rows, lanes, block_rows, dtype)


def op_zero(rows: int, lanes: int = LANES, block_rows: int | None = None,
            dtype=jnp.int32):
    return _pallas_rowwise(_zero_kernel, 0, rows, lanes, block_rows, dtype)


def op_maj3(rows: int, lanes: int = LANES, block_rows: int | None = None,
            dtype=jnp.int32):
    return _pallas_rowwise(_maj3_kernel, 3, rows, lanes, block_rows, dtype)


def op_and_popcount(rows: int, lanes: int = LANES,
                    block_rows: int | None = None, dtype=jnp.int32):
    """Fused popcount(A AND B) -> (rows, 1) int32 partial sums."""
    return _pallas_rowwise(_and_popcount_kernel, 2, rows, lanes,
                           block_rows, jnp.int32, out_lanes=1)


#: name -> (builder, arity). Arity is the number of array inputs.
OPS = {
    "and": (op_and, 2),
    "or": (op_or, 2),
    "xor": (op_xor, 2),
    "not": (op_not, 1),
    "copy": (op_copy, 1),
    "zero": (op_zero, 0),
    "maj3": (op_maj3, 3),
    "andpop": (op_and_popcount, 2),
}


@functools.lru_cache(maxsize=None)
def vmem_bytes(op: str, rows: int, lanes: int = LANES,
               block_rows: int | None = None) -> int:
    """Static VMEM footprint estimate for one grid step of ``op``.

    Used by the §Perf structural analysis (interpret=True gives no real
    VMEM numbers): sum of all operand blocks resident per step.
    """
    builder, arity = OPS[op]
    b = _block_rows(rows, block_rows)
    out_lanes = 1 if op == "andpop" else lanes
    per_lane = 4  # int32
    return b * per_lane * (arity * lanes + out_lanes)
