"""L2 — the JAX compute graphs that the rust coordinator executes as its
CPU-fallback path.

The paper's system dispatches each bulk memory operation either to the
PUD substrate (in-DRAM, when operands are subarray-co-located and
row-aligned) or to the host CPU. Our host-CPU path is this module:
batched bulk operators over row-shaped buffers, each calling the L1
Pallas kernel (``kernels/bitwise.py``), jit-lowered once by ``aot.py``
to HLO text and executed from rust via PJRT.

Shape-bucketing: HLO is shape-specialized, so we lower every op at a
small set of row-count buckets (vLLM-style). The rust runtime
(rust/src/runtime/exe_cache.rs) picks the largest bucket <= remaining
rows and loops; the tail goes through progressively smaller buckets.

All buffers are ``(rows, LANES) int32`` — one DRAM row per array row.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import bitwise

#: Row-count buckets lowered ahead of time. Powers of 8-ish keep the
#: executable cache small (4 entries/op) while bounding tail waste;
#: with greedy bucketing any request is covered by <= 2x the optimal
#: number of dispatches.
ROW_BUCKETS = (1, 8, 64, 256)

LANES = bitwise.LANES


def make_bulk_op(op: str, rows: int, lanes: int = LANES) -> Callable:
    """Build the L2 graph for one (op, rows) bucket.

    Returns a function of ``arity`` arrays of shape (rows, lanes) int32
    returning a 1-tuple (the AOT bridge lowers with return_tuple=True).
    """
    builder, arity = bitwise.OPS[op]
    computation = builder(rows, lanes)

    if arity == 0:
        def fn():
            return (computation(),)
    elif arity == 1:
        def fn(x):
            return (computation(x),)
    elif arity == 2:
        def fn(x, y):
            return (computation(x, y),)
    else:
        def fn(x, y, z):
            return (computation(x, y, z),)
    fn.__name__ = f"bulk_{op}_r{rows}"
    return fn, arity


def make_bitmap_scan(rows: int, lanes: int = LANES) -> Callable:
    """Fused bitmap-index scan: total = sum(popcount(A AND B)).

    The motivating database workload for Ambit-style PUD (bitmap index
    intersections); used by examples/database_scan.rs. The AND runs on
    the Pallas kernel; the final scalar reduce is plain jnp and fuses
    into the same HLO module.
    """
    andpop = bitwise.op_and_popcount(rows, lanes)

    def fn(x, y):
        per_row = andpop(x, y)              # (rows, 1) partial counts
        return (jnp.sum(per_row, dtype=jnp.int32).reshape((1, 1)),)

    fn.__name__ = f"bitmap_scan_r{rows}"
    return fn, 2


def example_args(arity: int, rows: int, lanes: int = LANES):
    """ShapeDtypeStructs used to trace/lower a bucket."""
    spec = jax.ShapeDtypeStruct((rows, lanes), jnp.int32)
    return (spec,) * arity


#: Every entry point lowered by aot.py: name -> (fn factory, arity).
#: Keys are the artifact base names ("<op>_r<rows>").
def entry_points():
    eps = {}
    for op in bitwise.OPS:
        for rows in ROW_BUCKETS:
            fn, arity = make_bulk_op(op, rows)
            eps[f"{op}_r{rows}"] = (fn, arity, rows)
    for rows in ROW_BUCKETS:
        fn, arity = make_bitmap_scan(rows)
        eps[f"bitmapscan_r{rows}"] = (fn, arity, rows)
    return eps
