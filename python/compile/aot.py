"""AOT bridge: lower every L2 entry point to HLO *text* artifacts.

Run once at build time (``make artifacts``); rust loads the text via
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU
client. Text — NOT ``.serialize()`` — is the interchange format: jax
>= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.
(See /opt/xla-example/README.md and gen_hlo.py.)

Outputs, under --out (default ../artifacts):
  <name>.hlo.txt        one per entry point ("and_r8", "bitmapscan_r64", ...)
  manifest.tsv          name / op / rows / lanes / arity / dtype / file
The manifest is the runtime's source of truth for the executable cache.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, fn, arity: int, rows: int) -> str:
    args = model.example_args(arity, rows)
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated entry-point names (debug aid)")
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)

    eps = model.entry_points()
    if ns.only:
        keep = set(ns.only.split(","))
        eps = {k: v for k, v in eps.items() if k in keep}

    manifest_rows = []
    for name, (fn, arity, rows) in sorted(eps.items()):
        text = lower_entry(name, fn, arity, rows)
        path = os.path.join(ns.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        op = name.rsplit("_r", 1)[0]
        manifest_rows.append(
            (name, op, rows, model.LANES, arity, "i32", f"{name}.hlo.txt"))
        print(f"  {name}: {len(text)} chars -> {path}")

    with open(os.path.join(ns.out, "manifest.tsv"), "w") as f:
        f.write("# name\top\trows\tlanes\tarity\tdtype\tfile\n")
        for row in manifest_rows:
            f.write("\t".join(str(c) for c in row) + "\n")
    print(f"wrote {len(manifest_rows)} artifacts + manifest.tsv to {ns.out}")


if __name__ == "__main__":
    main()
