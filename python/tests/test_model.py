"""L2 tests: entry-point inventory, shapes, and HLO lowering sanity.

These validate the build-time contract between python and the rust
runtime: every manifest entry lowers to parseable HLO text with the
declared arity/shape, and the bitmap-scan fusion returns the exact
scalar the oracle predicts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import bitwise, ref


def test_entry_point_inventory():
    eps = model.entry_points()
    # 8 bulk ops x 4 buckets + bitmapscan x 4 buckets
    assert len(eps) == (len(bitwise.OPS) + 1) * len(model.ROW_BUCKETS)
    for op in bitwise.OPS:
        for rows in model.ROW_BUCKETS:
            assert f"{op}_r{rows}" in eps
    for rows in model.ROW_BUCKETS:
        assert f"bitmapscan_r{rows}" in eps


def test_entry_point_arity_matches_ops():
    eps = model.entry_points()
    for name, (_fn, arity, rows) in eps.items():
        op = name.rsplit("_r", 1)[0]
        if op == "bitmapscan":
            assert arity == 2
        else:
            assert arity == bitwise.OPS[op][1]
        assert rows in model.ROW_BUCKETS


@pytest.mark.parametrize("op,rows", [("and", 1), ("zero", 8), ("copy", 1),
                                     ("maj3", 1)])
def test_bulk_op_executes(op, rows):
    fn, arity = model.make_bulk_op(op, rows, lanes=64)
    rng = np.random.default_rng(1)
    xs = tuple(jnp.asarray(rng.integers(-2**31, 2**31, size=(rows, 64),
                                        dtype=np.int64).astype(np.int32))
               for _ in range(arity))
    out = fn(*xs)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (rows, 64)
    if op == "zero":
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.zeros((rows, 64), np.int32))
    elif op == "copy":
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(xs[0]))


def test_bitmap_scan_scalar_matches_oracle():
    rows, lanes = 8, 32
    fn, arity = model.make_bitmap_scan(rows, lanes)
    assert arity == 2
    rng = np.random.default_rng(2)
    x, y = (jnp.asarray(rng.integers(0, 2**32, size=(rows, lanes),
                                     dtype=np.uint64).astype(np.uint32)
                        .view(np.int32)) for _ in range(2))
    (got,) = fn(x, y)
    assert got.shape == (1, 1)
    want = int(np.asarray(ref.ref_and_popcount(x, y)).sum())
    assert int(np.asarray(got)[0, 0]) == want


@pytest.mark.parametrize("name", ["and_r1", "zero_r1", "not_r1",
                                  "bitmapscan_r1"])
def test_lowering_produces_hlo_text(name):
    eps = model.entry_points()
    fn, arity, rows = eps[name]
    text = aot.lower_entry(name, fn, arity, rows)
    # Plausible HLO text: module header + ROOT instruction + tuple return
    assert text.startswith("HloModule")
    assert "ROOT" in text
    assert "s32[" in text
    # return_tuple=True => root is a tuple shape
    assert "(s32[" in text


def test_lowered_parameter_count_matches_arity():
    eps = model.entry_points()
    for name in ["and_r1", "not_r1", "zero_r1", "maj3_r1"]:
        fn, arity, rows = eps[name]
        text = aot.lower_entry(name, fn, arity, rows)
        # count distinct parameter instructions in the entry computation
        nparams = text.count("parameter(")
        assert nparams >= arity  # nested computations may add more
        if arity == 0:
            assert "parameter(0)" not in text.split("ENTRY")[1]


def test_example_args_shapes():
    args = model.example_args(2, 8, 16)
    assert len(args) == 2
    assert all(a.shape == (8, 16) and a.dtype == jnp.int32 for a in args)
