"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (rows x lanes) and dtypes; every comparison is
bit-exact (assert_array_equal — these are integer bitwise ops, not
float math). This is the CORE correctness signal for the CPU-fallback
path the rust coordinator executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitwise, ref

jax.config.update("jax_enable_x64", False)

# Lanes must be meaningful but small enough for fast interpret-mode
# runs; hardware lanes (2048) are exercised in the AOT smoke test.
LANE_CHOICES = (8, 32, 128)
DTYPES = (jnp.int32, jnp.uint32)


def _np_dtype(dt):
    return np.int32 if dt == jnp.int32 else np.uint32


def make_inputs(seed, arity, rows, lanes, dt=jnp.int32):
    rng = np.random.default_rng(seed)
    return tuple(
        jnp.asarray(rng.integers(0, 2**32, size=(rows, lanes),
                                 dtype=np.uint64).astype(np.uint32)
                    .view(_np_dtype(dt)))
        for _ in range(arity)
    )


@pytest.mark.parametrize("op", sorted(bitwise.OPS))
def test_op_matches_ref_fixed_shape(op):
    """Every op, canonical small shape, kernel vs oracle bit-exact."""
    builder, arity = bitwise.OPS[op]
    rows, lanes = 4, 64
    computation = builder(rows, lanes)
    xs = make_inputs(0xC0FFEE, arity, rows, lanes)
    got = computation(*xs)
    if op == "zero":
        want = ref.ref_zero(rows, lanes)
    else:
        want = ref.REF_OPS[op][0](*xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("op", ["and", "copy", "zero"])
def test_op_hardware_lane_width(op):
    """The real artifact shape: full 2048-lane DRAM row."""
    builder, arity = bitwise.OPS[op]
    computation = builder(2, bitwise.LANES)
    xs = make_inputs(7, arity, 2, bitwise.LANES)
    got = computation(*xs)
    if op == "zero":
        want = ref.ref_zero(2, bitwise.LANES)
    else:
        want = ref.REF_OPS[op][0](*xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    op=st.sampled_from(sorted(bitwise.OPS)),
    rows=st.integers(min_value=1, max_value=24),
    lanes=st.sampled_from(LANE_CHOICES),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_op_matches_ref_hypothesis(op, rows, lanes, dt, seed):
    """Hypothesis sweep: arbitrary rows (incl. counts not divisible by
    the default block), multiple lane widths and dtypes."""
    if op == "andpop":
        dt = jnp.int32  # fused popcount path is defined over i32
    builder, arity = bitwise.OPS[op]
    computation = builder(rows, lanes, dtype=dt)
    xs = make_inputs(seed, arity, rows, lanes, dt)
    got = computation(*xs)
    if op == "zero":
        want = ref.ref_zero(rows, lanes, dt)
    else:
        want = ref.REF_OPS[op][0](*xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=16),
    lanes=st.sampled_from(LANE_CHOICES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ambit_identities(rows, lanes, seed):
    """Substrate identities the rust PUD model relies on:
    maj(A,B,0) == AND, maj(A,B,~0) == OR, XOR via AND/NOT composition."""
    a, b = make_inputs(seed, 2, rows, lanes)
    zeros = jnp.zeros_like(a)
    ones = jnp.full_like(a, -1)
    maj = bitwise.op_maj3(rows, lanes)
    np.testing.assert_array_equal(np.asarray(maj(a, b, zeros)),
                                  np.asarray(a & b))
    np.testing.assert_array_equal(np.asarray(maj(a, b, ones)),
                                  np.asarray(a | b))
    # Ambit composes XOR as (A AND NOT B) OR (NOT A AND B).
    np.testing.assert_array_equal(np.asarray((a & ~b) | (~a & b)),
                                  np.asarray(a ^ b))


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=12),
    lanes=st.sampled_from(LANE_CHOICES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_maj3_commutative(rows, lanes, seed):
    """TRA is order-insensitive: maj(a,b,c) == maj(c,a,b) == maj(b,c,a)."""
    a, b, c = make_inputs(seed, 3, rows, lanes)
    maj = bitwise.op_maj3(rows, lanes)
    first = np.asarray(maj(a, b, c))
    np.testing.assert_array_equal(first, np.asarray(maj(c, a, b)))
    np.testing.assert_array_equal(first, np.asarray(maj(b, c, a)))


def test_popcount_extremes():
    zero = jnp.zeros((2, 8), jnp.int32)
    np.testing.assert_array_equal(np.asarray(ref.ref_popcount_i32(zero)),
                                  np.zeros((2, 8), np.int32))
    allones = jnp.full((2, 8), -1, jnp.int32)
    np.testing.assert_array_equal(np.asarray(ref.ref_popcount_i32(allones)),
                                  np.full((2, 8), 32, np.int32))


def test_popcount_single_bit_positions():
    vals = jnp.asarray(
        [[np.uint32(1 << i).astype(np.uint32).view(np.int32)
          for i in range(32)]], dtype=jnp.int32)
    got = ref.ref_popcount_i32(vals)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.ones((1, 32), np.int32))


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_andpop_equals_numpy_bitcount(rows, seed):
    """Cross-check the SWAR popcount against numpy's unpackbits."""
    x, y = make_inputs(seed, 2, rows, 32)
    got = np.asarray(bitwise.op_and_popcount(rows, 32)(x, y))[:, 0]
    raw = (np.asarray(x).view(np.uint32) & np.asarray(y).view(np.uint32))
    want = np.array([
        np.unpackbits(raw[r].view(np.uint8)).sum() for r in range(rows)
    ], dtype=np.int32)
    np.testing.assert_array_equal(got, want)


def test_vmem_estimate_structural():
    """Structural §Perf helper: footprint = blk_rows*(arity*lanes+out)*4."""
    assert bitwise.vmem_bytes("and", 8) == 3 * 8 * 2048 * 4
    assert bitwise.vmem_bytes("maj3", 8) == 4 * 8 * 2048 * 4
    assert bitwise.vmem_bytes("zero", 8) == 1 * 8 * 2048 * 4
    assert bitwise.vmem_bytes("andpop", 8) == 8 * (2 * 2048 + 1) * 4
    assert bitwise.vmem_bytes("and", 1) < bitwise.vmem_bytes("and", 8)


def test_block_rows_divisibility():
    """_block_rows always divides rows and never exceeds the request."""
    for rows in range(1, 50):
        b = bitwise._block_rows(rows, None)
        assert rows % b == 0
        assert 1 <= b <= min(rows, bitwise.DEFAULT_BLOCK_ROWS)
